//! The `parlsh worker --listen <addr>` process: hosts one worker *slot*'s
//! set of stage copies (paper: node = set of copies; with
//! `cluster.replication` > 1 each logical node is served by several slots,
//! see `net::cluster`) behind the socket transport.
//!
//! Lifecycle: bind, print `PARLSH_WORKER_LISTEN <addr>` on stdout (the one
//! and only stdout write — the launcher reads it to learn the bound port),
//! accept connections, then dispatch. The first frame on each accepted
//! connection identifies the sender: `Hello` (the driver — carries slot
//! assignment, placement, config and digest) or `PeerHello` (another
//! worker). Per-connection reader threads decode frames into one internal
//! *bounded* channel (`net.queue_frames`: a full queue blocks the reader,
//! pushing backpressure onto the TCP sender instead of buffering an
//! unbounded backlog); the main thread owns all stage state and processes
//! events in
//! arrival order, which preserves the per-connection FIFO that the build
//! state-identity contract relies on (each BI/DP copy sees the single IR
//! source in emission order, exactly like the in-process executors).
//!
//! Emissions route by `Placement` + the local replica live mask: same-slot
//! → local queue (a free delivery, like the in-process meters), head node
//! → driver connection, other slots → lazily-dialed peer connections, with
//! per-query `CandidateReq` hops pinned to one live replica by the shared
//! deterministic `pick_slot` rule. All outgoing frames are aggregated per
//! peer (`stream.agg_bytes`) and flushed at idle, and the worker's
//! `TrafficMeter` is charged with real encoded frame bytes — shipped back
//! on every `FlushReq` barrier.
//!
//! Replication plumbing: `Membership` frames refresh the live mask and the
//! peer address table (a rejoined slot gets a fresh OS port); `Ping` is
//! answered with `Pong(epoch)` so the driver's failure detector hears from
//! us; `Restore` replays a sibling replica's `StateDump` into this
//! (fresh) worker; `PersistReq` saves the hosted shard through
//! `coordinator::persist::save_shard`. A worker started with
//! `--shard=PATH` reloads that file before `HelloOk` and announces the
//! shard's epoch, letting the driver fence stale state (`net::cluster`).
//! Sends to a dead peer never kill the worker: the frame is dropped, the
//! slot is marked dead locally, and the driver's retarget logic owns
//! re-dispatching the affected queries.
//!
//! Shutdown is typed both ways: a `Shutdown` frame exits cleanly; any
//! failure path fires a drop-guard that sends the driver a `Stopped` frame
//! (the socket rendition of the threaded executor's drop-guard) carrying
//! the failure's rendered cause — e.g. a duplicate `StoreObject` surfaces
//! as a typed [`crate::store::StoreError`] through this path instead of a
//! panic — so the driver's admission loop can never hang on a dead worker
//! and its error report names the actual invariant that broke.

use crate::config::{Config, ReplicaRoute, SocketConfig};
use crate::coordinator::persist;
use crate::dataflow::exec::{BiHandler, DpHandler, StageHandler};
use crate::dataflow::message::{Dest, Msg, StageKind};
use crate::dataflow::metrics::{TrafficMeter, WorkStats};
use crate::dataflow::Placement;
use crate::net::cluster::pick_slot;
use crate::net::peer::{connect_retry, PeerConn};
use crate::net::wire::{self, FrameKind, Hello, NodeState};
use crate::runtime::{Ranker, SimdRanker};
use crate::stages::{BiState, DpState};
use crate::util::cli::Args;
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{self, Receiver, SyncSender, TryRecvError};
use std::time::Duration;

/// Writes that stall past this horizon fail the worker loudly (typed IO
/// error → `Stopped` drop-guard) instead of hanging: mirrors the
/// driver-side write timeout guarding the bounded-queue backpressure
/// cycle (see `net::driver` module docs).
const WRITE_STALL_TIMEOUT: Duration = Duration::from_secs(120);

/// Events the reader threads feed the dispatch loop.
enum Ev {
    Hello(Box<Hello>, TcpStream),
    Msg(Dest, Msg),
    Done(u32),
    Flush(u32),
    StateReq,
    Ping,
    Membership { epoch: u64, table: Vec<(bool, String)> },
    Restore { epoch: u64, dump: Vec<u8> },
    PersistReq { epoch: u64, path: String },
    Shutdown,
    Closed { driver: bool, err: String },
    Fatal(String),
}

/// CLI entry: `parlsh worker [--listen=ADDR | --join=ADDR] [--shard=PATH]
/// [--set net.*=...]`. `--join` is `--listen` under its discovery-mode
/// name: a worker bound at a `[net] hosts` table address, waiting for the
/// driver to find it instead of being spawned by it. `--shard` reloads a
/// `persist::save_shard` file before the handshake so a restarted worker
/// can rejoin a session without a state transfer (fenced by config digest
/// + epoch on the driver side).
pub fn run(args: &Args) -> Result<()> {
    let cfg = Config::load(args)?;
    let listen = args
        .opt("join")
        .or_else(|| args.opt("listen"))
        .map(str::to_string)
        .unwrap_or_else(|| cfg.sock.listen.clone());
    serve(&listen, &cfg.sock, args.opt("shard"))
}

/// Bind, announce, and dispatch until `Shutdown` (or a fatal error).
pub fn serve(listen: &str, sock: &SocketConfig, shard: Option<&str>) -> Result<()> {
    let listener =
        TcpListener::bind(listen).with_context(|| format!("worker bind {listen}"))?;
    let addr = listener.local_addr()?;
    // The launcher parses this line; everything else goes to stderr.
    println!("PARLSH_WORKER_LISTEN {addr}");
    std::io::stdout().flush().ok();

    // Bounded reader→dispatch queue (`net.queue_frames`): a full queue
    // blocks the connection's reader thread, which stops draining its TCP
    // socket, which backpressures the sender — instead of buffering an
    // unbounded frame backlog in worker memory. The dataflow is a DAG
    // (driver → BI → DP → driver) and the driver always drains its side,
    // so bounded queues here cannot deadlock the pipeline.
    let (tx, rx) = mpsc::sync_channel::<Ev>(sock.queue_frames.max(1));
    let max_frame = sock.max_frame_bytes;
    std::thread::spawn(move || accept_loop(listener, tx, max_frame));
    dispatch(rx, sock.clone(), shard)
}

fn accept_loop(listener: TcpListener, tx: SyncSender<Ev>, max_frame: usize) {
    for conn in listener.incoming() {
        let Ok(stream) = conn else { continue };
        stream.set_nodelay(true).ok();
        let tx = tx.clone();
        std::thread::spawn(move || conn_reader(stream, tx, max_frame));
    }
}

/// One reader per accepted connection: identify the sender by its first
/// frame, then translate frames into events until EOF.
fn conn_reader(mut stream: TcpStream, tx: SyncSender<Ev>, max_frame: usize) {
    let first = match wire::read_frame(&mut stream, max_frame) {
        Ok(f) => f,
        // A connection that closes before identifying itself (e.g. a
        // port probe) is not worth killing the worker over.
        Err(_) => return,
    };
    let from_driver = match first.kind {
        FrameKind::Hello => match wire::decode_hello(&first.payload) {
            Ok(h) => {
                let writer = match stream.try_clone() {
                    Ok(w) => w,
                    Err(e) => {
                        let _ = tx.send(Ev::Fatal(format!("clone driver conn: {e}")));
                        return;
                    }
                };
                if tx.send(Ev::Hello(Box::new(h), writer)).is_err() {
                    return;
                }
                true
            }
            Err(e) => {
                let _ = tx.send(Ev::Fatal(format!("bad handshake: {e}")));
                return;
            }
        },
        FrameKind::PeerHello => {
            if let Err(e) = wire::decode_peer_hello(&first.payload) {
                let _ = tx.send(Ev::Fatal(format!("bad peer hello: {e}")));
                return;
            }
            false
        }
        other => {
            let _ = tx.send(Ev::Fatal(format!("unexpected first frame {other:?}")));
            return;
        }
    };
    reader_rest(stream, tx, max_frame, from_driver)
}

fn reader_rest(mut stream: TcpStream, tx: SyncSender<Ev>, max_frame: usize, from_driver: bool) {
    loop {
        match wire::read_frame(&mut stream, max_frame) {
            Ok(f) => {
                let ev = match f.kind {
                    FrameKind::Stage => match wire::decode_stage(&f.payload) {
                        Ok((d, m)) => Ev::Msg(d, m),
                        Err(e) => Ev::Fatal(format!("bad stage frame: {e}")),
                    },
                    FrameKind::Done => match wire::decode_qid(&f.payload) {
                        Ok(qid) => Ev::Done(qid),
                        Err(e) => Ev::Fatal(format!("bad done frame: {e}")),
                    },
                    FrameKind::FlushReq => match wire::decode_qid(&f.payload) {
                        Ok(seq) => Ev::Flush(seq),
                        Err(e) => Ev::Fatal(format!("bad flush frame: {e}")),
                    },
                    FrameKind::StateReq => Ev::StateReq,
                    FrameKind::Ping => Ev::Ping,
                    FrameKind::Membership => match wire::decode_membership(&f.payload) {
                        Ok((epoch, table)) => Ev::Membership { epoch, table },
                        Err(e) => Ev::Fatal(format!("bad membership frame: {e}")),
                    },
                    FrameKind::Restore => match wire::decode_restore(&f.payload) {
                        Ok((epoch, dump)) => Ev::Restore { epoch, dump: dump.to_vec() },
                        Err(e) => Ev::Fatal(format!("bad restore frame: {e}")),
                    },
                    FrameKind::PersistReq => match wire::decode_persist_req(&f.payload) {
                        Ok((epoch, path)) => Ev::PersistReq { epoch, path },
                        Err(e) => Ev::Fatal(format!("bad persist frame: {e}")),
                    },
                    FrameKind::Shutdown => Ev::Shutdown,
                    other => Ev::Fatal(format!("unexpected frame {other:?}")),
                };
                let last = matches!(ev, Ev::Fatal(_) | Ev::Shutdown);
                if tx.send(ev).is_err() || last {
                    return;
                }
            }
            Err(e) => {
                let _ = tx.send(Ev::Closed { driver: from_driver, err: e.to_string() });
                return;
            }
        }
    }
}

/// Drop-guard: tells the driver this worker is dying (fires on unwind and
/// on error returns; disarmed only by a clean `Shutdown`). Error paths
/// that know *why* record it in `reason` before returning, so the
/// driver's `Stopped` report names the broken invariant (a duplicate
/// store, a bad frame) instead of a generic epitaph.
struct StopGuard {
    conn: Option<TcpStream>,
    reason: String,
}

impl StopGuard {
    fn disarm(&mut self) {
        self.conn = None;
    }
}

impl Drop for StopGuard {
    fn drop(&mut self) {
        if let Some(conn) = &mut self.conn {
            let frame =
                wire::encode_frame(FrameKind::Stopped, &wire::encode_stopped(&self.reason));
            let _ = conn.write_all(&frame);
        }
    }
}

fn dispatch(rx: Receiver<Ev>, sock: SocketConfig, shard: Option<&str>) -> Result<()> {
    // Await the handshake before anything else; the driver holds the
    // workload back until every worker replied HelloOk, so no peer can
    // reach us with messages before our state exists.
    let (hello, driver_stream) = match rx.recv().context("events closed before handshake")? {
        Ev::Hello(h, w) => (*h, w),
        Ev::Fatal(e) => bail!("{e}"),
        Ev::Closed { err, .. } => bail!("connection closed before handshake: {err}"),
        _ => bail!("frame before handshake"),
    };

    let placement = Placement::new(&hello.cluster);
    let my = hello.node; // slot id, replica-major (dataflow::Placement)
    let n_slots = placement.total_slots();
    if (my as usize) >= n_slots {
        bail!("assigned slot {my} out of range (0..{n_slots})");
    }
    if hello.peers.len() != n_slots {
        bail!("peer table has {} entries, expected {n_slots}", hello.peers.len());
    }
    let my_logical = placement.node_of_slot(my);
    let route = hello.cluster.replica_route;
    let dim = hello.dim as usize;
    let agg = hello.stream.agg_bytes;

    // The set of stage copies this slot hosts: its logical node's share of
    // the placement (every replica slot of a node hosts identical copies).
    let mut bis: Vec<BiState> = Vec::new();
    let mut bi_idx: HashMap<u16, usize> = HashMap::new();
    for c in 0..placement.bi_copies as u16 {
        if placement.node_of(StageKind::Bi, c) == my_logical {
            bi_idx.insert(c, bis.len());
            bis.push(BiState::new(c, placement.ag_copies, hello.stream.max_candidates));
        }
    }
    let mut dps: Vec<DpState> = Vec::new();
    let mut dp_idx: HashMap<u16, usize> = HashMap::new();
    for c in 0..placement.dp_copies as u16 {
        if placement.node_of(StageKind::Dp, c) == my_logical {
            dp_idx.insert(c, dps.len());
            // Per-query plans: the ranking depth k now arrives on every
            // CandidateReq (wire v3), so the DP store needs no frozen k.
            dps.push(DpState::new(c, dim, placement.ag_copies, hello.stream.dedup));
        }
    }

    // A restarted worker reloads its shard before answering the handshake
    // and announces the shard's epoch; the driver fences it (digest +
    // epoch, `net::cluster::validate_join`) before admitting any traffic.
    // A missing/unreadable/mismatched file means "join empty" (epoch 0) —
    // the driver then restores us from a live sibling replica.
    let mut epoch: u64 = 0;
    if let Some(path) = shard {
        match persist::load_shard(path, hello.digest) {
            Ok((shard_epoch, state)) => {
                replay_state(&state, &mut bis, &bi_idx, &mut dps, &dp_idx)
                    .with_context(|| format!("replay shard {path}"))?;
                epoch = shard_epoch;
                eprintln!("worker slot {my}: reloaded shard {path} at epoch {epoch}");
            }
            Err(e) => {
                eprintln!("worker slot {my}: shard {path} unusable ({e}); joining empty");
            }
        }
    }

    // Workers rank with the SIMD tier — bit-identical to the scalar
    // oracle and therefore to the inline differential baseline
    // (DESIGN.md §Transports, §Kernels).
    let ranker = SimdRanker { dim };

    let mut guard = StopGuard {
        conn: driver_stream.try_clone().ok(),
        reason: "worker dispatch terminated".to_string(),
    };
    driver_stream.set_write_timeout(Some(WRITE_STALL_TIMEOUT)).ok();
    let mut driver = PeerConn::new(driver_stream, agg);
    driver.send_now(&wire::encode_frame(
        FrameKind::HelloOk,
        &wire::encode_hello_ok(my, hello.digest, epoch),
    ))?;

    let mut peers: Vec<Option<PeerConn>> = (0..n_slots).map(|_| None).collect();
    let mut addrs: Vec<String> = hello.peers.clone();
    let mut live: Vec<bool> = vec![true; n_slots];
    let mut meter = fresh_meter(agg);
    let mut queue: VecDeque<(Dest, Msg)> = VecDeque::new();
    let mut scratch: Vec<(Dest, Msg)> = Vec::new();

    loop {
        let ev = match rx.try_recv() {
            Ok(ev) => ev,
            Err(TryRecvError::Empty) => {
                // Idle: everything queued so far must reach the wire before
                // we block, or closed-loop admission would deadlock.
                driver.flush()?;
                flush_peers(&mut peers, &mut live, my);
                match rx.recv() {
                    Ok(ev) => ev,
                    Err(_) => bail!("event channel closed"),
                }
            }
            Err(TryRecvError::Disconnected) => bail!("event channel closed"),
        };
        match ev {
            Ev::Msg(dest, msg) => {
                queue.push_back((dest, msg));
                let drained = drain(
                    &mut queue,
                    &mut bis,
                    &bi_idx,
                    &mut dps,
                    &dp_idx,
                    &ranker,
                    &placement,
                    my,
                    route,
                    &addrs,
                    &mut live,
                    &sock,
                    agg,
                    &mut driver,
                    &mut peers,
                    &mut meter,
                    &mut scratch,
                );
                if let Err(e) = drained {
                    // Record the cause (e.g. a typed StoreError on a buggy
                    // replica fan-out) so the Stopped frame carries it.
                    guard.reason = format!("{e:#}");
                    return Err(e);
                }
            }
            Ev::Done(qid) => {
                for dp in dps.iter_mut() {
                    dp.finish_query(qid);
                }
            }
            Ev::Flush(seq) => {
                flush_peers(&mut peers, &mut live, my);
                meter.flush();
                // Ship (and reset) the phase work counters of every hosted
                // copy alongside the meter, so driver-side work accounting
                // is complete per phase — not head-only (DESIGN.md
                // §Transports; the simnet cost model consumes these).
                let mut work: Vec<(StageKind, u16, WorkStats)> = Vec::new();
                for bi in bis.iter_mut() {
                    // Refresh the memory gauge right before the take: the
                    // counters are phase deltas, the gauge is current state.
                    bi.work.bytes_resident = bi.bytes_resident();
                    work.push((StageKind::Bi, bi.copy, std::mem::take(&mut bi.work)));
                }
                for dp in dps.iter_mut() {
                    dp.work.bytes_resident = dp.bytes_resident();
                    work.push((StageKind::Dp, dp.copy, std::mem::take(&mut dp.work)));
                }
                driver.send_now(&wire::encode_frame(
                    FrameKind::FlushAck,
                    &wire::encode_flush_ack(seq, &meter, &work),
                ))?;
                meter = fresh_meter(agg);
            }
            Ev::StateReq => {
                driver.send_now(&wire::encode_frame(
                    FrameKind::StateDump,
                    &wire::encode_state_dump(&bis, &dps),
                ))?;
            }
            Ev::Ping => {
                // Liveness probe: answer immediately (ahead of any queued
                // stage traffic) with our epoch, so the driver's failure
                // detector sees both "alive" and "in sync".
                driver.send_now(&wire::encode_frame(
                    FrameKind::Pong,
                    &wire::encode_epoch(epoch),
                ))?;
            }
            Ev::Membership { epoch: e, table } => {
                if table.len() != n_slots {
                    bail!("membership table has {} slots, expected {n_slots}", table.len());
                }
                epoch = e;
                for (slot, (is_live, addr)) in table.into_iter().enumerate() {
                    // A changed address (rejoined replica on a fresh OS
                    // port) or a death both invalidate a cached connection.
                    if addrs[slot] != addr || !is_live {
                        peers[slot] = None;
                    }
                    addrs[slot] = addr;
                    // Never mark ourselves dead: if the driver still talks
                    // to us, we serve (our entry flips back on rejoin).
                    live[slot] = is_live || slot == my as usize;
                }
            }
            Ev::Restore { epoch: e, dump } => {
                // Replay a sibling replica's state dump into this (fresh)
                // worker, adopt the driver's epoch, and acknowledge.
                let state = wire::decode_state_dump(&dump)?;
                if let Err(err) = replay_state(&state, &mut bis, &bi_idx, &mut dps, &dp_idx)
                    .with_context(|| format!("restore into slot {my}"))
                {
                    guard.reason = format!("{err:#}");
                    return Err(err);
                }
                epoch = e;
                driver.send_now(&wire::encode_frame(
                    FrameKind::RestoreOk,
                    &wire::encode_slot_ack(my),
                ))?;
            }
            Ev::PersistReq { epoch: e, path } => {
                persist::save_shard(&path, e, hello.digest, &bis, &dps)
                    .with_context(|| format!("persist slot {my} to {path}"))?;
                epoch = e;
                driver.send_now(&wire::encode_frame(
                    FrameKind::PersistOk,
                    &wire::encode_slot_ack(my),
                ))?;
            }
            Ev::Shutdown => {
                driver.flush()?;
                flush_peers(&mut peers, &mut live, my);
                guard.disarm();
                return Ok(());
            }
            Ev::Closed { driver: true, err } => bail!("driver connection lost: {err}"),
            // A peer closing its sending side is normal wind-down; a peer
            // *crash* is detected by the driver on its own connection.
            Ev::Closed { driver: false, .. } => {}
            Ev::Fatal(e) => bail!("{e}"),
            Ev::Hello(..) => bail!("duplicate handshake"),
        }
    }
}

fn fresh_meter(agg: usize) -> TrafficMeter {
    // header_bytes = 0: each frame already carries its real 12-byte header
    // in its encoded length, so link bytes equal actual bytes-on-wire.
    let mut m = TrafficMeter::new(agg);
    m.header_bytes = 0;
    m
}

/// Flush every live peer connection; a flush failure marks that slot dead
/// locally (dropping the buffered frames) instead of killing the worker —
/// the driver detects the crash on its own connection and retargets the
/// affected queries.
fn flush_peers(peers: &mut [Option<PeerConn>], live: &mut [bool], my: u16) {
    for (slot, conn) in peers.iter_mut().enumerate() {
        let Some(p) = conn else { continue };
        if let Err(e) = p.flush() {
            eprintln!("worker slot {my}: peer slot {slot} flush failed ({e}); marking dead");
            *conn = None;
            live[slot] = false;
        }
    }
}

/// Replay a decoded [`NodeState`] (shard file or `Restore` frame) into the
/// hosted stage copies. Only copies this slot actually hosts are legal —
/// anything else means the dump came from a different placement.
fn replay_state(
    state: &NodeState,
    bis: &mut [BiState],
    bi_idx: &HashMap<u16, usize>,
    dps: &mut [DpState],
    dp_idx: &HashMap<u16, usize>,
) -> Result<()> {
    for (copy, buckets) in &state.bis {
        let &i = bi_idx
            .get(copy)
            .with_context(|| format!("restored BI copy {copy} not hosted here"))?;
        for (key, refs) in buckets {
            for &(id, dp) in refs {
                bis[i].on_index_ref(*key, id, dp);
            }
        }
    }
    for (copy, objs) in &state.dps {
        let &i = dp_idx
            .get(copy)
            .with_context(|| format!("restored DP copy {copy} not hosted here"))?;
        for (id, v) in objs {
            dps[i]
                .try_store(*id, v)
                .with_context(|| format!("replaying DP copy {copy}"))?;
        }
    }
    Ok(())
}

/// Resolve an emission's destination to one worker slot: the logical
/// node's live replicas (ascending slot order — the canonical order every
/// router shares), then the deterministic per-query pick. `None` means no
/// replica survives; the caller drops the frame and lets the driver's
/// failure detector degrade or retarget the query.
fn route_slot(
    placement: &Placement,
    route: ReplicaRoute,
    live: &[bool],
    node: u16,
    m: &Msg,
) -> Option<u16> {
    let slots: Vec<u16> = (0..placement.replication)
        .map(|r| placement.slot_of(node, r))
        .filter(|&s| live[s as usize])
        .collect();
    if slots.is_empty() {
        return None;
    }
    match m {
        // Per-query hops pin to one replica per logical node — every
        // sender agrees because the pick is a pure function of the same
        // inputs (net::cluster::replica).
        Msg::CandidateReq { qid, v, .. } | Msg::Query { qid, v, .. } => {
            Some(pick_slot(route, &slots, *qid, v))
        }
        // Anything else a worker emits toward a worker node would be
        // build-path (driver-originated in this dataflow); lowest live
        // replica, deterministically.
        _ => Some(slots[0]),
    }
}

/// Process queued local deliveries to quiescence, routing emissions by
/// placement + live mask (local re-queue / driver / lazily-dialed peer).
#[allow(clippy::too_many_arguments)]
fn drain(
    queue: &mut VecDeque<(Dest, Msg)>,
    bis: &mut [BiState],
    bi_idx: &HashMap<u16, usize>,
    dps: &mut [DpState],
    dp_idx: &HashMap<u16, usize>,
    ranker: &dyn Ranker,
    placement: &Placement,
    my: u16,
    route: ReplicaRoute,
    addrs: &[String],
    live: &mut [bool],
    sock: &SocketConfig,
    agg: usize,
    driver: &mut PeerConn,
    peers: &mut [Option<PeerConn>],
    meter: &mut TrafficMeter,
    scratch: &mut Vec<(Dest, Msg)>,
) -> Result<()> {
    while let Some((dest, msg)) = queue.pop_front() {
        match dest.stage {
            StageKind::Bi => {
                let &i = bi_idx
                    .get(&dest.copy)
                    .with_context(|| format!("BI copy {} not hosted on slot {my}", dest.copy))?;
                BiHandler { bi: &mut bis[i] }.on_msg(msg, scratch);
            }
            StageKind::Dp => {
                let &i = dp_idx
                    .get(&dest.copy)
                    .with_context(|| format!("DP copy {} not hosted on slot {my}", dest.copy))?;
                // Stores go through the fallible path: a duplicate id is a
                // replica fan-out bug, and on this transport it must stop
                // the worker with a typed Stopped frame, not a panic.
                match msg {
                    Msg::StoreObject { id, v } => dps[i]
                        .try_store(id, &v)
                        .with_context(|| format!("DP copy {} on slot {my}", dest.copy))?,
                    other => DpHandler { dp: &mut dps[i], ranker: Some(ranker) }
                        .on_msg(other, scratch),
                }
            }
            other => bail!("stage {other:?} routed to worker slot {my}"),
        }
        for (d, m) in scratch.drain(..) {
            let node = placement.node_of(d.stage, d.copy);
            if node == placement.head_node {
                let frame = wire::stage_frame(d, &m);
                meter.send(my, node, frame.len());
                driver.send(&frame)?;
                continue;
            }
            let Some(slot) = route_slot(placement, route, live, node, &m) else {
                // No live replica: drop — the driver fails or retargets
                // the query itself when it notices the dead node.
                eprintln!(
                    "worker slot {my}: no live replica for node {node}, dropping {:?} emission",
                    d.stage
                );
                continue;
            };
            if slot == my {
                // Same-slot delivery: free, like the in-process executors.
                meter.send(my, my, 0);
                queue.push_back((d, m));
            } else {
                let frame = wire::stage_frame(d, &m);
                meter.send(my, slot, frame.len());
                let sent = peer_conn(peers, slot, my, addrs, sock, agg)
                    .and_then(|p| p.send(&frame).map_err(anyhow::Error::from));
                if let Err(e) = sent {
                    // Dead peer: never fatal here. Drop the frame and mark
                    // the slot dead so later picks avoid it; the driver
                    // owns retargeting the queries this frame served.
                    eprintln!("worker slot {my}: send to slot {slot} failed ({e}); marking dead");
                    peers[slot as usize] = None;
                    live[slot as usize] = false;
                }
            }
        }
    }
    Ok(())
}

/// Fetch (dialing on first use) the connection to another worker slot.
fn peer_conn<'p>(
    peers: &'p mut [Option<PeerConn>],
    slot: u16,
    my: u16,
    addrs: &[String],
    sock: &SocketConfig,
    agg: usize,
) -> Result<&'p mut PeerConn> {
    let entry = &mut peers[slot as usize];
    if entry.is_none() {
        let stream = connect_retry(&addrs[slot as usize], sock.connect_retries, sock.retry_ms)
            .with_context(|| format!("slot {my} dialing slot {slot} at {}", addrs[slot as usize]))?;
        stream.set_write_timeout(Some(WRITE_STALL_TIMEOUT)).ok();
        let mut pc = PeerConn::new(stream, agg);
        pc.send_now(&wire::encode_frame(
            FrameKind::PeerHello,
            &wire::encode_peer_hello(my),
        ))?;
        *entry = Some(pc);
    }
    Ok(entry.as_mut().unwrap())
}
