//! Replicated, self-healing worker topology (DESIGN.md §Cluster topology).
//!
//! Three concerns, deliberately separated from the driver's event loop:
//!
//! * [`membership`] — the live/dead/address table shared by the driver and
//!   the stream loop, the session epoch (a count of completed write
//!   phases), and the join-validation rule that fences a rejoining worker
//!   by config digest and epoch;
//! * [`replica`] — deterministic replica selection. Every sender routing a
//!   `CandidateReq` for the same query must pick the *same* replica (the
//!   DP dedup state for a query lives on exactly one replica per logical
//!   node), so selection is a pure function of the routing strategy, the
//!   live-slot set, and the query — never of per-connection state.
//!
//! The slot layout itself lives on [`crate::dataflow::Placement`]
//! (replica-major: slot `r * n_logical + node`), so replication = 1
//! degenerates to the unreplicated topology bit-for-bit.

pub mod membership;
pub mod replica;

pub use membership::{validate_join, ClusterState, RejoinPath};
pub use replica::pick_slot;
