//! Membership + epoch bookkeeping for the replicated worker topology.
//!
//! The driver owns one [`ClusterState`] per `NetSession`. The stream loop
//! marks replicas dead when their connection drops or their heartbeat goes
//! silent; `NetSession::heal_worker` marks them live again after a
//! successful rejoin handshake. Workers hold their own *local* copy of the
//! live mask, refreshed by `Membership` frames, so worker→worker
//! `CandidateReq` routing agrees with the driver's.
//!
//! The **epoch** counts completed write phases (index build blocks and
//! object inserts). A worker rejoining mid-session presents the epoch of
//! the shard it reloaded; anything but "exactly current" or "empty, please
//! restore me" is fenced with a typed [`WireError`] so a stale or hostile
//! process can never serve old data into a live stream.

use crate::dataflow::Placement;
use crate::net::wire::WireError;

/// How a validated rejoiner gets its shard back.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejoinPath {
    /// The worker reloaded a shard at exactly the current epoch (from its
    /// `--shard` file) — nothing to transfer.
    FastPath,
    /// The worker is empty (epoch 0 of a session that has advanced): the
    /// driver pulls a `StateDump` from a live sibling replica and replays
    /// it into the rejoiner via a `Restore` frame.
    NeedsRestore,
}

/// Validate a (re)joining worker's handshake against the session.
///
/// `got_digest`/`got_epoch` are what the worker announced in `HelloOk`;
/// `want_digest`/`cur_epoch` are the session's. The special case
/// `cur_epoch == 0` (nothing written yet) admits only empty workers —
/// a non-zero shard epoch against a fresh session is as stale as an old
/// one against an advanced session.
pub fn validate_join(
    want_digest: u64,
    cur_epoch: u64,
    got_digest: u64,
    got_epoch: u64,
) -> Result<RejoinPath, WireError> {
    if got_digest != want_digest {
        return Err(WireError::DigestMismatch { got: got_digest, want: want_digest });
    }
    if got_epoch == cur_epoch {
        return Ok(RejoinPath::FastPath);
    }
    if got_epoch == 0 {
        return Ok(RejoinPath::NeedsRestore);
    }
    Err(WireError::EpochFenced { got: got_epoch, want: cur_epoch })
}

/// Live/dead/address table for every worker slot, plus the session epoch.
#[derive(Clone, Debug)]
pub struct ClusterState {
    /// Completed write phases (builds + inserts).
    pub epoch: u64,
    /// Liveness per slot (`Placement::total_slots()` entries).
    pub live: Vec<bool>,
    /// Announced listen address per slot (workers dial these for
    /// worker→worker hops; refreshed on rejoin — a respawned worker gets
    /// a new OS-assigned port).
    pub addrs: Vec<String>,
}

impl ClusterState {
    pub fn new(addrs: Vec<String>) -> ClusterState {
        ClusterState { epoch: 0, live: vec![true; addrs.len()], addrs }
    }

    pub fn mark_dead(&mut self, slot: u16) {
        self.live[slot as usize] = false;
    }

    pub fn mark_live(&mut self, slot: u16, addr: String) {
        self.live[slot as usize] = true;
        self.addrs[slot as usize] = addr;
    }

    /// Live slots replicating a logical node, ascending by slot id. The
    /// ordering matters: every router must see the same list.
    pub fn live_slots_of(&self, placement: &Placement, node: u16) -> Vec<u16> {
        (0..placement.replication)
            .map(|r| placement.slot_of(node, r))
            .filter(|&s| self.live[s as usize])
            .collect()
    }

    /// Does any replica of this logical node survive?
    pub fn node_has_live(&self, placement: &Placement, node: u16) -> bool {
        (0..placement.replication).any(|r| self.live[placement.slot_of(node, r) as usize])
    }

    pub fn n_live(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    pub fn n_dead(&self) -> usize {
        self.live.len() - self.n_live()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn placement() -> Placement {
        Placement::new(&ClusterConfig {
            bi_nodes: 1,
            dp_nodes: 2,
            replication: 2,
            ..Default::default()
        })
    }

    fn state(p: &Placement) -> ClusterState {
        ClusterState::new((0..p.total_slots()).map(|s| format!("127.0.0.1:{}", 7500 + s)).collect())
    }

    #[test]
    fn liveness_tracks_replicas_per_logical_node() {
        let p = placement();
        let mut cs = state(&p);
        assert_eq!(cs.n_live(), 6);
        assert_eq!(cs.live_slots_of(&p, 1), vec![1, 4]);

        cs.mark_dead(4);
        assert_eq!(cs.live_slots_of(&p, 1), vec![1]);
        assert!(cs.node_has_live(&p, 1));
        assert_eq!(cs.n_dead(), 1);

        cs.mark_dead(1);
        assert!(cs.live_slots_of(&p, 1).is_empty());
        assert!(!cs.node_has_live(&p, 1));
        // other logical nodes are untouched
        assert!(cs.node_has_live(&p, 0));
        assert!(cs.node_has_live(&p, 2));

        // rejoin with a fresh OS-assigned address
        cs.mark_live(4, "127.0.0.1:9999".into());
        assert_eq!(cs.live_slots_of(&p, 1), vec![4]);
        assert_eq!(cs.addrs[4], "127.0.0.1:9999");
    }

    #[test]
    fn join_validation_fences_digest_and_epoch() {
        // exact epoch match: fast path (covers the fresh-empty handshake
        // 0 == 0 and a shard reloaded at the current epoch)
        assert!(matches!(validate_join(7, 0, 7, 0), Ok(RejoinPath::FastPath)));
        assert!(matches!(validate_join(7, 3, 7, 3), Ok(RejoinPath::FastPath)));
        // empty worker against an advanced session: restore
        assert!(matches!(validate_join(7, 3, 7, 0), Ok(RejoinPath::NeedsRestore)));
        // stale shard: fenced, typed
        match validate_join(7, 3, 7, 2) {
            Err(WireError::EpochFenced { got: 2, want: 3 }) => {}
            other => panic!("want EpochFenced, got {other:?}"),
        }
        // future epoch (a shard from some other session's timeline): fenced
        assert!(matches!(validate_join(7, 3, 7, 9), Err(WireError::EpochFenced { .. })));
        // wrong config digest beats everything else
        match validate_join(7, 3, 8, 3) {
            Err(WireError::DigestMismatch { got: 8, want: 7 }) => {}
            other => panic!("want DigestMismatch, got {other:?}"),
        }
    }
}
