//! Deterministic replica selection for query-path routing.
//!
//! Per-query messages (`QueryVec` fan-out, `CandidateReq` BI→DP hops,
//! `Done` cleanup) must land on exactly one replica per logical node, and
//! *every* sender — the driver and any worker slot — must pick the same
//! one: DP dedup state for a query is built on the chosen replica only.
//! So selection is a pure function of `(strategy, live slots, query)`,
//! with the live-slot list in ascending slot order (the one canonical
//! order every `ClusterState`/local live mask produces).
//!
//! Two strategies (`cluster.replica_route`):
//!
//! * **round-robin** — `qid mod live`: balanced and content-blind.
//! * **layered/entropy** (Bahmani et al., arXiv 1210.7057) — an FNV-1a
//!   hash of the query vector's bit pattern picks the replica. Identical
//!   and near-identical (re-submitted) queries pin to one replica, which
//!   is where layered LSH wins network/cache locality; `experiment net`
//!   measures the real-bytes difference per strategy.

use crate::config::ReplicaRoute;
use crate::net::wire::{fnv1a64, FNV64_OFFSET};

/// Pick the slot that serves this query on its logical node.
///
/// `live` is the node's live replica slots, ascending (from
/// `ClusterState::live_slots_of` or a worker's local mask). Panics on an
/// empty list — callers must degrade the query (retarget or fail the
/// stream) *before* routing at a node with no survivors.
pub fn pick_slot(route: ReplicaRoute, live: &[u16], qid: u32, v: &[f32]) -> u16 {
    assert!(!live.is_empty(), "routing with no live replicas");
    match route {
        ReplicaRoute::RoundRobin => live[qid as usize % live.len()],
        ReplicaRoute::Layered => {
            let mut bytes = Vec::with_capacity(v.len() * 4);
            for x in v {
                bytes.extend_from_slice(&x.to_bits().to_le_bytes());
            }
            live[(fnv1a64(FNV64_OFFSET, &bytes) % live.len() as u64) as usize]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles_live_slots() {
        let live = vec![2u16, 5, 8];
        let v = [0.5f32; 4];
        let picks: Vec<u16> = (0..6).map(|q| pick_slot(ReplicaRoute::RoundRobin, &live, q, &v)).collect();
        assert_eq!(picks, vec![2, 5, 8, 2, 5, 8]);
        // shrinking the live set reroutes deterministically
        assert_eq!(pick_slot(ReplicaRoute::RoundRobin, &[5], 1, &v), 5);
    }

    #[test]
    fn layered_is_content_addressed() {
        let live = vec![1u16, 4];
        let a = [1.0f32, 2.0, 3.0];
        let b = [1.0f32, 2.0, 3.5];
        // same vector, any qid → same replica (that's the pinning property)
        let pa = pick_slot(ReplicaRoute::Layered, &live, 0, &a);
        assert_eq!(pa, pick_slot(ReplicaRoute::Layered, &live, 99, &a));
        assert!(live.contains(&pa));
        assert!(live.contains(&pick_slot(ReplicaRoute::Layered, &live, 0, &b)));
        // over many distinct vectors both replicas get traffic
        let mut seen = std::collections::HashSet::new();
        for i in 0..32 {
            let v = [i as f32, (i * 7) as f32];
            seen.insert(pick_slot(ReplicaRoute::Layered, &live, 0, &v));
        }
        assert_eq!(seen.len(), 2, "layered routing never spread across replicas");
    }

    #[test]
    #[should_panic(expected = "no live replicas")]
    fn empty_live_set_is_a_caller_bug() {
        pick_slot(ReplicaRoute::RoundRobin, &[], 0, &[]);
    }
}
