//! Real multi-process distribution: the socket transport behind the
//! executor seam (DESIGN.md §Transports).
//!
//! Where `simnet` *models* the paper's cluster, this subsystem *runs* it:
//! the same five-stage dataflow crosses real TCP connections between OS
//! processes, so partition-strategy claims ("30% fewer messages") are
//! exercised over an actual wire and the `TrafficMeter` carries measured
//! bytes, not the `wire_size` model.
//!
//! * [`wire`] — versioned, length-framed, checksummed binary codec for
//!   every `Msg` variant plus the control frames (handshake, barriers,
//!   acks, snapshots, typed shutdown);
//! * [`peer`] — per-peer connection management with the stream layer's
//!   packet aggregation (`stream.agg_bytes`);
//! * [`worker`] — the `parlsh worker --listen <addr>` process hosting one
//!   worker slot's set of stage copies (via the shared `Placement`);
//! * [`driver`] — [`NetSession`] (spawn or discover N workers, handshake,
//!   typed shutdown, no leaked processes) and [`SocketExecutor`], the
//!   `Executor` impl the coordinator drivers run build and search through;
//! * [`cluster`] — replicated topology: membership/epoch bookkeeping,
//!   join validation (digest + epoch fencing), and deterministic
//!   replica routing (round-robin and Bahmani-style layered/entropy) —
//!   DESIGN.md §Cluster topology;
//! * [`front`] — the poll-based serving front door: `parlsh serve
//!   --listen` multiplexes external clients onto one resident
//!   `IndexSession` through a readiness-driven event loop, plus the
//!   [`front::Client`] library struct behind `parlsh query --connect`.
//!
//! Uses `std::net` only — no new dependencies, consistent with the
//! offline-clean build.

pub mod cluster;
pub mod driver;
pub mod front;
pub mod peer;
pub mod wire;
pub mod worker;

pub use driver::{NetSession, SocketExecutor};
