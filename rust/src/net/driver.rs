//! The socket-transport launcher and executor: spawns `parlsh worker`
//! processes on loopback, handshakes them, and drives the five-stage
//! pipeline across real OS processes through the transport-agnostic
//! [`Executor`] seam.
//!
//! Topology follows the paper via the shared [`Placement`]: the *driver*
//! process is the head node (IR/QR ingress + every AG copy, where global
//! top-k reduction and completion accounting live), and each BI/DP node is
//! one worker process. A [`NetSession`] outlives individual phases —
//! worker-side BI/DP state persists between `build_index_on` and
//! `search_on`, exactly like the in-process `Cluster` does — and ends with
//! a typed `Shutdown` that joins every worker (no leaked processes).
//!
//! [`SocketExecutor::run`] mirrors the threaded executor's admission loop:
//! closed-loop batched admission via `Workload::window`, completion events
//! from the (local) AG copies, and per-query `Done` acks fanned out to the
//! DP-hosting workers — the ack closes the `stream.inflight` loop and
//! tears down remote dedup state. A worker that dies mid-phase surfaces as
//! a typed `Stopped`/closed event and fails the phase loudly instead of
//! hanging the admission loop. Traffic accounting is real: every encoded
//! frame is charged with its actual on-wire length (header included) on
//! the sender's meter, and worker meters come back in `FlushAck` barriers
//! at phase end, so `ExecReport::meter` holds measured per-link TCP bytes,
//! not the `wire_size` model.

use crate::config::Config;
use crate::dataflow::exec::{ExecReport, Executor, StageHandler, StageHandlers, Workload};
use crate::dataflow::message::{Dest, Msg, StageKind};
use crate::dataflow::metrics::{TrafficMeter, WorkStats};
use crate::dataflow::Placement;
use crate::net::peer::{connect_retry, PeerConn};
use crate::net::wire::{self, FrameKind, Hello, NodeState};
use crate::stages::aggregator::QueryResult;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::VecDeque;
use std::io::{BufRead, BufReader};
use std::net::TcpStream;
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// How long to wait on control responses (handshake, barriers, snapshots).
const CONTROL_TIMEOUT: Duration = Duration::from_secs(30);
/// How long a phase may sit with no event at all before we call it wedged.
const PHASE_STALL_TIMEOUT: Duration = Duration::from_secs(120);

/// Events the per-worker reader threads feed the driver.
enum DriverEv {
    HelloOk { from: u16, node: u16, digest: u64 },
    Msg { from: u16, dest: Dest, msg: Msg },
    FlushAck {
        from: u16,
        seq: u32,
        meter: TrafficMeter,
        work: Vec<(StageKind, u16, WorkStats)>,
    },
    State { from: u16, state: NodeState },
    Stopped { from: u16, reason: String },
    Closed { from: u16, err: String },
}

struct Session {
    peers: Vec<PeerConn>,
    ev_rx: Receiver<DriverEv>,
    placement: Placement,
    /// Worker nodes hosting at least one DP copy (get per-query `Done`s).
    dp_hosts: Vec<u16>,
    flush_seq: u32,
}

/// An [`Executor`] that runs BI/DP stages on remote worker processes. The
/// local `bis`/`dps` handlers in [`StageHandlers`] are intentionally not
/// driven — that state lives (and persists across phases) in the workers;
/// fetch it with [`NetSession::fetch_state`].
pub struct SocketExecutor {
    inner: Mutex<Session>,
}

impl Executor for SocketExecutor {
    fn run(
        &self,
        placement: &Placement,
        stages: StageHandlers<'_>,
        workload: Workload<'_>,
    ) -> ExecReport {
        let mut s = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        match s.run_phase(placement, stages, workload) {
            Ok(report) => report,
            // Mirror the threaded executor: a dead stage (here: worker)
            // resurfaces loudly instead of wedging the admission loop.
            Err(e) => panic!("socket phase failed: {e}"),
        }
    }
}

impl Session {
    fn run_phase(
        &mut self,
        placement: &Placement,
        stages: StageHandlers<'_>,
        workload: Workload<'_>,
    ) -> Result<ExecReport> {
        if *placement != self.placement {
            bail!("phase placement differs from the placement workers were launched with");
        }
        let Session { peers, ev_rx, dp_hosts, flush_seq, .. } = self;
        let n_workers = peers.len();
        let head = placement.head_node;
        let n_queries = workload.n_queries;
        let window = workload.window;

        let StageHandlers { head: mut head_stage, bis, dps, mut ags } = stages;
        drop(bis); // BI/DP state lives in the workers, not behind these
        drop(dps);

        let mut meter = TrafficMeter::new(workload.agg_bytes);
        meter.header_bytes = 0; // frames carry their real header in len
        let mut results: Vec<Vec<(f32, u32)>> = vec![Vec::new(); n_queries];
        let mut per_query_secs = vec![0f64; n_queries];
        let mut dispatch_ts: Vec<Instant> = vec![Instant::now(); n_queries];
        let mut local_q: VecDeque<(Dest, Msg)> = VecDeque::new();
        let mut emitted: Vec<(Dest, Msg)> = Vec::new();
        let mut comps: Vec<QueryResult> = Vec::new();
        let mut completed = 0usize;
        let mut in_flight = 0usize;
        let mut items = workload.items.peekable();
        let mut items_done = false;

        loop {
            // Admit while the window allows; items without a qid (index
            // blocks) are never windowed — same policy as the threaded
            // executor.
            while !items_done {
                let next_is_query = match items.peek() {
                    None => {
                        items_done = true;
                        break;
                    }
                    Some(m) => m.qid().is_some(),
                };
                if next_is_query && window != 0 && in_flight >= window {
                    break;
                }
                let item = items.next().expect("peeked non-empty");
                let item_qid = item.qid();
                head_stage.on_msg(item, &mut emitted);
                if let Some(qid) = item_qid {
                    dispatch_ts[qid as usize] = Instant::now();
                    in_flight += 1;
                }
                for (dest, msg) in emitted.drain(..) {
                    let node = placement.node_of(dest.stage, dest.copy);
                    if node == head {
                        meter.send(head, head, 0);
                        local_q.push_back((dest, msg));
                    } else {
                        let frame = wire::stage_frame(dest, &msg);
                        meter.send(head, node, frame.len());
                        peers[node as usize].send(&frame)?;
                    }
                }
                drain_local(
                    &mut local_q,
                    &mut ags,
                    &mut comps,
                    &mut results,
                    &mut per_query_secs,
                    &dispatch_ts,
                    &mut completed,
                    &mut in_flight,
                    peers,
                    dp_hosts,
                )?;
            }
            if items_done && completed >= n_queries {
                break;
            }
            // Block for remote events — but only after everything queued
            // reached the wire, or the closed loop deadlocks.
            for p in peers.iter_mut() {
                p.flush()?;
            }
            match ev_rx.recv_timeout(PHASE_STALL_TIMEOUT) {
                Ok(DriverEv::Msg { dest, msg, .. }) => {
                    local_q.push_back((dest, msg));
                    drain_local(
                        &mut local_q,
                        &mut ags,
                        &mut comps,
                        &mut results,
                        &mut per_query_secs,
                        &dispatch_ts,
                        &mut completed,
                        &mut in_flight,
                        peers,
                        dp_hosts,
                    )?;
                }
                Ok(DriverEv::Stopped { from, reason }) => {
                    bail!("worker {from} stopped mid-phase: {reason}")
                }
                Ok(DriverEv::Closed { from, err }) => {
                    bail!("worker {from} connection lost mid-phase: {err}")
                }
                Ok(_) => bail!("unexpected control frame mid-phase"),
                Err(RecvTimeoutError::Timeout) => bail!(
                    "phase stalled: {completed}/{n_queries} queries after {}s of silence",
                    PHASE_STALL_TIMEOUT.as_secs()
                ),
                Err(RecvTimeoutError::Disconnected) => bail!("all worker readers exited"),
            }
        }

        // Phase barrier: collect every worker's real bytes-on-wire meter
        // plus its per-copy work counters (so the report's work accounting
        // covers the remote BI/DP copies, not just the head).
        *flush_seq += 1;
        let seq = *flush_seq;
        let req = wire::encode_frame(FrameKind::FlushReq, &wire::encode_qid(seq));
        for p in peers.iter_mut() {
            p.send_now(&req)?;
        }
        meter.flush();
        let mut remote_work: Vec<(StageKind, u16, WorkStats)> = Vec::new();
        let mut acks = 0usize;
        while acks < n_workers {
            match ev_rx.recv_timeout(CONTROL_TIMEOUT) {
                Ok(DriverEv::FlushAck { seq: s, meter: m, work, from }) => {
                    if s != seq {
                        bail!("worker {from} acked barrier {s}, expected {seq}");
                    }
                    meter.merge(&m);
                    remote_work.extend(work);
                    acks += 1;
                }
                Ok(DriverEv::Stopped { from, reason }) => {
                    bail!("worker {from} stopped at barrier: {reason}")
                }
                Ok(DriverEv::Closed { from, err }) => {
                    bail!("worker {from} connection lost at barrier: {err}")
                }
                Ok(_) => bail!("unexpected frame at phase barrier"),
                Err(e) => bail!("phase barrier: {e}"),
            }
        }
        Ok(ExecReport { results, per_query_secs, meter, work: remote_work })
    }
}

/// Deliver queued head-node messages (always AG — the head hosts no BI/DP
/// copy) and handle completions: record result + latency, shrink the
/// admission window, and fan the `Done` ack to every DP-hosting worker.
#[allow(clippy::too_many_arguments)]
fn drain_local(
    local_q: &mut VecDeque<(Dest, Msg)>,
    ags: &mut [Box<dyn StageHandler + '_>],
    comps: &mut Vec<QueryResult>,
    results: &mut [Vec<(f32, u32)>],
    per_query_secs: &mut [f64],
    dispatch_ts: &[Instant],
    completed: &mut usize,
    in_flight: &mut usize,
    peers: &mut [PeerConn],
    dp_hosts: &[u16],
) -> Result<()> {
    let mut emitted: Vec<(Dest, Msg)> = Vec::new();
    while let Some((dest, msg)) = local_q.pop_front() {
        if dest.stage != StageKind::Ag {
            bail!("{:?} message addressed to the head node", dest.stage);
        }
        let ag = ags
            .get_mut(dest.copy as usize)
            .ok_or_else(|| anyhow!("no AG copy {}", dest.copy))?;
        ag.on_msg(msg, &mut emitted);
        debug_assert!(emitted.is_empty(), "AG emitted a message");
        emitted.clear();
        ag.take_completions(comps);
        for (qid, hits) in comps.drain(..) {
            per_query_secs[qid as usize] =
                dispatch_ts[qid as usize].elapsed().as_secs_f64();
            results[qid as usize] = hits;
            *completed += 1;
            *in_flight = in_flight.saturating_sub(1);
            // The completion ack: closes the inflight loop and drops the
            // remote per-query dedup state. Control — never metered.
            let done = wire::encode_frame(FrameKind::Done, &wire::encode_qid(qid));
            for &node in dp_hosts {
                peers[node as usize].send(&done)?;
            }
        }
    }
    Ok(())
}

/// A running multi-process cluster: worker children + the socket executor.
/// Shut it down explicitly with [`NetSession::shutdown`] for a typed exit;
/// dropping the session kills any still-running workers (no leaks either
/// way).
pub struct NetSession {
    children: Vec<Child>,
    exec: SocketExecutor,
}

impl NetSession {
    /// Launch workers using this very binary's `worker` subcommand (the
    /// normal path for `parlsh` itself). Override the binary with the
    /// `PARLSH_WORKER_BIN` env var when the current executable is not
    /// `parlsh` (e.g. a test harness).
    pub fn launch(cfg: &Config, dim: usize) -> Result<NetSession> {
        let bin = match std::env::var("PARLSH_WORKER_BIN") {
            Ok(p) => std::path::PathBuf::from(p),
            Err(_) => std::env::current_exe().context("resolve current executable")?,
        };
        Self::launch_with_bin(&bin, cfg, dim)
    }

    /// Launch one worker process per BI/DP node of `cfg.cluster` from an
    /// explicit binary path, connect, and handshake. `dim` is the dataset
    /// dimensionality workers size their DP stores with.
    pub fn launch_with_bin(bin: &Path, cfg: &Config, dim: usize) -> Result<NetSession> {
        let placement = Placement::new(&cfg.cluster);
        let n_workers = placement.total_nodes() - 1;
        // Every worker binds the same configured address, so a fixed port
        // can only ever host one worker — reject it up front instead of
        // letting worker 1 die on EADDRINUSE before announcing itself.
        if n_workers > 1 && !cfg.sock.listen.ends_with(":0") {
            bail!(
                "net.listen `{}` pins a port but {n_workers} workers must bind it; \
                 use port 0 (OS-assigned) for local multi-worker launches",
                cfg.sock.listen
            );
        }
        let mut session = NetSession {
            children: Vec::with_capacity(n_workers),
            exec: SocketExecutor {
                inner: Mutex::new(Session {
                    peers: Vec::new(),
                    ev_rx: mpsc::channel().1, // replaced below
                    placement: placement.clone(),
                    dp_hosts: (cfg.cluster.bi_nodes
                        ..cfg.cluster.bi_nodes + cfg.cluster.dp_nodes)
                        .map(|n| n as u16)
                        .collect(),
                    flush_seq: 0,
                }),
            },
        };

        // Spawn first, then read each announced listen address. Workers
        // must not write anything else to stdout.
        for node in 0..n_workers {
            let child = Command::new(bin)
                .arg("worker")
                .arg(format!("--listen={}", cfg.sock.listen))
                .arg("--set")
                .arg(format!("net.max_frame_bytes={}", cfg.sock.max_frame_bytes))
                .arg("--set")
                .arg(format!("net.connect_retries={}", cfg.sock.connect_retries))
                .arg("--set")
                .arg(format!("net.retry_ms={}", cfg.sock.retry_ms))
                .stdin(Stdio::null())
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit())
                .spawn()
                .with_context(|| format!("spawn worker {node} from {}", bin.display()))?;
            session.children.push(child);
        }
        let mut addrs = Vec::with_capacity(n_workers);
        for (node, child) in session.children.iter_mut().enumerate() {
            let stdout = child.stdout.take().expect("piped stdout");
            let mut line = String::new();
            BufReader::new(stdout)
                .read_line(&mut line)
                .with_context(|| format!("read worker {node} listen line"))?;
            let addr = line
                .trim()
                .strip_prefix("PARLSH_WORKER_LISTEN ")
                .ok_or_else(|| anyhow!("worker {node} announced `{}`", line.trim()))?
                .to_string();
            addrs.push(addr);
        }

        // Connect + handshake each worker; reader threads feed one channel.
        let digest = wire::config_digest(dim as u32, &cfg.lsh, &cfg.cluster, &cfg.stream);
        let (ev_tx, ev_rx) = mpsc::channel::<DriverEv>();
        let mut peers = Vec::with_capacity(n_workers);
        for node in 0..n_workers {
            let stream = connect_retry(
                &addrs[node],
                cfg.sock.connect_retries,
                cfg.sock.retry_ms,
            )
            .with_context(|| format!("connect worker {node} at {}", addrs[node]))?;
            let reader = stream.try_clone().context("clone worker conn")?;
            spawn_reader(reader, node as u16, ev_tx.clone(), cfg.sock.max_frame_bytes);
            let mut pc = PeerConn::new(stream, cfg.stream.agg_bytes);
            let hello = Hello {
                node: node as u16,
                dim: dim as u32,
                peers: addrs.clone(),
                lsh: cfg.lsh,
                cluster: cfg.cluster,
                stream: cfg.stream,
                digest,
            };
            pc.send_now(&wire::encode_frame(FrameKind::Hello, &wire::encode_hello(&hello)))?;
            peers.push(pc);
        }

        // Every worker must accept the same config digest before any
        // workload flows.
        let mut ok = vec![false; n_workers];
        let mut acked = 0usize;
        while acked < n_workers {
            match ev_rx.recv_timeout(CONTROL_TIMEOUT) {
                Ok(DriverEv::HelloOk { from, node, digest: d }) => {
                    if node != from {
                        bail!("worker on conn {from} claims node {node}");
                    }
                    if d != digest {
                        bail!("worker {from} config digest mismatch");
                    }
                    if std::mem::replace(&mut ok[from as usize], true) {
                        bail!("worker {from} acked twice");
                    }
                    acked += 1;
                }
                Ok(DriverEv::Stopped { from, reason }) => {
                    bail!("worker {from} stopped during handshake: {reason}")
                }
                Ok(DriverEv::Closed { from, err }) => {
                    bail!("worker {from} closed during handshake: {err}")
                }
                Ok(_) => bail!("unexpected frame during handshake"),
                Err(e) => bail!("handshake: {e}"),
            }
        }

        {
            let inner = session.exec.inner.get_mut().unwrap_or_else(|p| p.into_inner());
            inner.peers = peers;
            inner.ev_rx = ev_rx;
        }
        Ok(session)
    }

    /// The executor to pass to `build_index_on` / `search_on`.
    pub fn executor(&self) -> &SocketExecutor {
        &self.exec
    }

    /// Snapshot every worker's BI buckets and DP objects (differential
    /// tests; one `(node, state)` pair per worker, node-sorted).
    pub fn fetch_state(&self) -> Result<Vec<(u16, NodeState)>> {
        let mut s = self.exec.inner.lock().unwrap_or_else(|p| p.into_inner());
        let Session { peers, ev_rx, .. } = &mut *s;
        let req = wire::encode_frame(FrameKind::StateReq, &[]);
        for p in peers.iter_mut() {
            p.send_now(&req)?;
        }
        let mut out = Vec::with_capacity(peers.len());
        while out.len() < peers.len() {
            match ev_rx.recv_timeout(CONTROL_TIMEOUT) {
                Ok(DriverEv::State { from, state }) => out.push((from, state)),
                Ok(DriverEv::Stopped { from, reason }) => {
                    bail!("worker {from} stopped during snapshot: {reason}")
                }
                Ok(DriverEv::Closed { from, err }) => {
                    bail!("worker {from} closed during snapshot: {err}")
                }
                Ok(_) => bail!("unexpected frame during snapshot"),
                Err(e) => bail!("state snapshot: {e}"),
            }
        }
        out.sort_by_key(|(node, _)| *node);
        Ok(out)
    }

    /// Typed shutdown: ask every worker to exit, then join them all,
    /// failing on any nonzero exit. Workers that ignore the request are
    /// killed (and reported) rather than leaked.
    pub fn shutdown(mut self) -> Result<()> {
        {
            let mut s = self.exec.inner.lock().unwrap_or_else(|p| p.into_inner());
            let frame = wire::encode_frame(FrameKind::Shutdown, &[]);
            for p in s.peers.iter_mut() {
                p.send_now(&frame)?;
            }
        }
        let mut children = std::mem::take(&mut self.children);
        for (node, child) in children.iter_mut().enumerate() {
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                match child.try_wait().with_context(|| format!("wait worker {node}"))? {
                    Some(status) if status.success() => break,
                    Some(status) => bail!("worker {node} exited with {status}"),
                    None if Instant::now() >= deadline => {
                        child.kill().ok();
                        child.wait().ok();
                        bail!("worker {node} ignored shutdown; killed");
                    }
                    None => std::thread::sleep(Duration::from_millis(10)),
                }
            }
        }
        Ok(())
    }
}

impl Drop for NetSession {
    fn drop(&mut self) {
        // Error paths only: `shutdown` drains `children` first.
        for child in &mut self.children {
            child.kill().ok();
            child.wait().ok();
        }
    }
}

fn spawn_reader(stream: TcpStream, from: u16, tx: Sender<DriverEv>, max_frame: usize) {
    std::thread::spawn(move || reader_loop(stream, from, tx, max_frame));
}

fn reader_loop(mut stream: TcpStream, from: u16, tx: Sender<DriverEv>, max_frame: usize) {
    loop {
        let frame = match wire::read_frame(&mut stream, max_frame) {
            Ok(f) => f,
            Err(e) => {
                let _ = tx.send(DriverEv::Closed { from, err: e.to_string() });
                return;
            }
        };
        let ev = match frame.kind {
            FrameKind::HelloOk => wire::decode_hello_ok(&frame.payload)
                .map(|(node, digest)| DriverEv::HelloOk { from, node, digest }),
            FrameKind::Stage => wire::decode_stage(&frame.payload)
                .map(|(dest, msg)| DriverEv::Msg { from, dest, msg }),
            FrameKind::FlushAck => wire::decode_flush_ack(&frame.payload)
                .map(|(seq, meter, work)| DriverEv::FlushAck { from, seq, meter, work }),
            FrameKind::StateDump => wire::decode_state_dump(&frame.payload)
                .map(|state| DriverEv::State { from, state }),
            FrameKind::Stopped => wire::decode_stopped(&frame.payload)
                .map(|reason| DriverEv::Stopped { from, reason }),
            other => Err(anyhow!("unexpected frame {other:?} from worker {from}")),
        };
        match ev {
            Ok(ev) => {
                let stop = matches!(ev, DriverEv::Stopped { .. });
                if tx.send(ev).is_err() || stop {
                    return;
                }
            }
            Err(e) => {
                let _ = tx.send(DriverEv::Closed { from, err: e.to_string() });
                return;
            }
        }
    }
}
