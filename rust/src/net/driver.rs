//! The socket-transport launcher and executor: spawns `parlsh worker`
//! processes on loopback, handshakes them, and drives the five-stage
//! pipeline across real OS processes through the transport-agnostic
//! [`Executor`] seam.
//!
//! Topology follows the paper via the shared [`Placement`]: the *driver*
//! process is the head node (IR/QR ingress + every AG copy, where global
//! top-k reduction and completion accounting live), and each BI/DP node is
//! one worker process. A [`NetSession`] outlives individual phases —
//! worker-side BI/DP state persists between `build_index_on` and
//! `search_on`, exactly like the in-process `Cluster` does — and ends with
//! a typed `Shutdown` that joins every worker (no leaked processes).
//!
//! Besides one-shot phase runs, [`SocketExecutor`] implements
//! [`Executor::open_stream`]: a dedicated admission thread takes over the
//! hot worker connections for the run's lifetime, submissions are admitted
//! the moment they arrive (no per-pump workload), and the
//! `FlushReq`/`FlushAck` meter barrier runs once per stream at `finish`
//! instead of once per pump.
//!
//! **Bounded wire fan-in.** The driver-side event queue — one channel
//! unifying every worker reader's decoded frames with a streaming run's
//! ingress — is *bounded* by `net.queue_frames` (the same knob that
//! bounds worker reader→dispatch queues): a full queue blocks the reader
//! threads, which stop draining their sockets, which backpressures the
//! workers' TCP senders instead of buffering an unbounded event backlog
//! in driver memory. Depth argument: in **closed-loop** operation
//! (`stream.inflight = W`) at most `W · (n_bi + n_dp + 1)` wire events
//! can be outstanding per the completion accounting (QueryMeta + BiMetas
//! + LocalTopKs per in-flight query), so a default-sized queue (1024
//! frames) never fills and the bound is free. In **open-loop** operation
//! the bound is what limits the *wire* backlog: fan-in beyond the queue
//! parks in kernel TCP buffers and ultimately in the workers' own
//! bounded queues, so pressure propagates along the dataflow DAG
//! (worker → driver is its last edge — the driver's admission loops
//! always drain this queue before blocking, which is what keeps the
//! cycle through `peers[..].send` unreachable at the default depth; size
//! `net.queue_frames` ≥ the expected per-query fan-in times the
//! concurrent query count). Streaming ingress shares the channel: the
//! blocking `submit` path parks in short ticks while it is full and
//! fails loudly (never wedges) if the admission thread is gone; the
//! non-blocking `try_submit` path treats a full channel as a decline,
//! exactly like a full backpressure window, so callers holding their own
//! locks are never parked here. Note what the bound does **not** cover:
//! ingress the admission loop has already accepted but deferred behind
//! the closed-loop window sits in its in-memory `pending` queue, whose
//! depth is governed end-to-end by `stream.pending_cap` (the session
//! gate; 0 = the caller chose unbounded) — same contract as the
//! in-process streaming runs.
//!
//! Residual hazard, and why it fails loudly instead of hanging: with
//! blocking IO, bounding the worker→driver edge weakens PR 4's DAG
//! argument ("the driver always drains its side") — under extreme
//! open-loop pressure a full cycle can wedge (driver blocked in a peer
//! `send` ⇢ worker not reading ⇢ worker blocked writing results ⇢
//! driver readers parked on the full queue ⇢ nobody drains). The
//! admission loop's recv-side stall clock cannot fire while the driver
//! is blocked in a *write*, so every driver↔worker socket carries a
//! write timeout at the same `PHASE_STALL_TIMEOUT` horizon: a wedged
//! cycle surfaces as a typed IO error that fails the phase/stream (and
//! tears the fleet down) rather than a silent permanent hang.
//! Closed-loop windows or `stream.pending_cap` keep the cycle
//! unreachable in the first place; removing it entirely is the
//! poll-based-IO ROADMAP item.
//!
//! [`SocketExecutor::run`] mirrors the threaded executor's admission loop:
//! closed-loop batched admission via `Workload::window`, completion events
//! from the (local) AG copies, and per-query `Done` acks fanned out to the
//! DP-hosting workers — the ack closes the `stream.inflight` loop and
//! tears down remote dedup state. A worker that dies mid-phase surfaces as
//! a typed `Stopped`/closed event and fails the phase loudly instead of
//! hanging the admission loop. Traffic accounting is real: every encoded
//! frame is charged with its actual on-wire length (header included) on
//! the sender's meter, and worker meters come back in `FlushAck` barriers
//! at phase end, so `ExecReport::meter` holds measured per-link TCP bytes,
//! not the `wire_size` model.
//!
//! **Cluster topology (DESIGN.md §Cluster topology).** PR 8 grows the
//! flat fleet into a replicated, self-healing one. Placement is
//! replica-major: `cluster.replication` copies of every BI/DP node, one
//! worker *slot* each. Membership is either *spawned* (loopback children,
//! OS-assigned ports announced on stdout — no fixed-port assumption) or
//! *discovery* via `[net] hosts` (workers started out of band with
//! `parlsh worker --join`, the session dials them). The shared
//! [`ClusterState`] table (slot liveness + addresses + session epoch)
//! feeds both the phase loop and the stream loop through [`ClusterCtl`]:
//! writes fan to every live replica (and refuse to run degraded), queries
//! route to exactly one live replica per logical node
//! ([`pick_slot`] — round-robin or layered/entropy-aware). Failure
//! detection is layered: broken pipes fail fast, and a heartbeat
//! (`net.heartbeat_ms` Pings, [`HEARTBEAT_MISSES`] strikes) catches
//! silent deaths mid-stream. A dead replica's in-flight queries are
//! cancelled and retargeted to survivors with fresh qids ≥
//! [`RETRY_BASE`]; the membership update is broadcast *before* the
//! retries are re-admitted so every sender routes them identically. A
//! restarted worker rejoins mid-session through [`NetSession::heal_worker`]
//! — epoch-fenced by [`validate_join`] (stale shards and wrong configs
//! get a typed [`crate::net::wire::WireError`] rejection), reloading
//! state from its persisted shard (fast path) or from a live sibling
//! replica via a `Restore` replay.

use crate::config::{Config, ReplicaRoute, SocketConfig};
use crate::dataflow::exec::{
    ExecReport, Executor, GateGuard, StageHandler, StageHandlers, StreamCompletion,
    StreamConfig, StreamGate, StreamReport, StreamRun, Workload,
};
use crate::dataflow::message::{Dest, Msg, StageKind};
use crate::dataflow::metrics::{TrafficMeter, WorkStats};
use crate::dataflow::Placement;
use crate::net::cluster::{pick_slot, validate_join, ClusterState, RejoinPath};
use crate::net::peer::{connect_retry, PeerConn};
use crate::net::wire::{self, FrameKind, Hello, NodeState};
use crate::stages::aggregator::QueryResult;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{BufRead, BufReader};
use std::net::TcpStream;
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How long a streaming submitter parks between attempts while the bounded
/// driver event queue is full. Only paid when wire fan-in saturates the
/// queue — the backpressure path, where event latency dominates anyway.
const EV_FULL_TICK: Duration = Duration::from_micros(200);

/// How long to wait on control responses (handshake, barriers, snapshots).
const CONTROL_TIMEOUT: Duration = Duration::from_secs(30);
/// How long a phase may sit with no event at all before we call it wedged.
const PHASE_STALL_TIMEOUT: Duration = Duration::from_secs(120);
/// Heartbeat intervals of silence from a live slot before it is declared
/// dead. Any event from the slot (Pong, stage traffic, acks) resets the
/// clock, so only a truly unresponsive process crosses this.
const HEARTBEAT_MISSES: u32 = 3;
/// Retried (retargeted) queries get fresh qids from here up, far above
/// any workload's dense 0..n range: the AG copies see every attempt as a
/// distinct query (their duplicate-qid assertions never fire), and the
/// stream can map the retry back to the original id at completion.
const RETRY_BASE: u32 = 0x8000_0000;

/// Events the per-worker reader threads feed the driver. `Ingress` and
/// `Finish` come from a streaming run's handle instead of a socket — one
/// unified channel stands in for a select over submissions + wire events.
enum DriverEv {
    HelloOk { from: u16, node: u16, digest: u64, epoch: u64 },
    Msg { from: u16, dest: Dest, msg: Msg },
    FlushAck {
        from: u16,
        seq: u32,
        meter: TrafficMeter,
        work: Vec<(StageKind, u16, WorkStats)>,
    },
    State { from: u16, state: NodeState },
    Stopped { from: u16, reason: String },
    Closed { from: u16, err: String },
    /// Heartbeat reply (carries the worker's current epoch).
    Pong { from: u16, epoch: u64 },
    /// A healed worker finished replaying a `Restore` dump.
    RestoreOk { from: u16, slot: u16 },
    /// A worker finished writing its shard for a `PersistReq`.
    PersistOk { from: u16, slot: u16 },
    /// Streaming submission ([`StreamRun::submit`]).
    Ingress(Msg),
    /// Streaming barrier: wind the run down at quiescence.
    Finish,
}

/// The slot a wire event came from (None for run-handle events). Used to
/// feed the heartbeat's per-slot liveness clock — any traffic counts.
fn ev_from(ev: &DriverEv) -> Option<u16> {
    match ev {
        DriverEv::HelloOk { from, .. }
        | DriverEv::Msg { from, .. }
        | DriverEv::FlushAck { from, .. }
        | DriverEv::State { from, .. }
        | DriverEv::Stopped { from, .. }
        | DriverEv::Closed { from, .. }
        | DriverEv::Pong { from, .. }
        | DriverEv::RestoreOk { from, .. }
        | DriverEv::PersistOk { from, .. } => Some(*from),
        DriverEv::Ingress(_) | DriverEv::Finish => None,
    }
}

/// The cluster view shared by the executor, the streaming admission thread
/// and [`NetSession`]: the membership table plus the routing knobs every
/// sender needs. Cloning shares the underlying [`ClusterState`].
#[derive(Clone)]
struct ClusterCtl {
    state: Arc<Mutex<ClusterState>>,
    route: ReplicaRoute,
    heartbeat: Duration,
}

impl ClusterCtl {
    fn is_live(&self, slot: u16) -> bool {
        self.state.lock().unwrap().live[slot as usize]
    }

    fn live_mask(&self) -> Vec<bool> {
        self.state.lock().unwrap().live.clone()
    }

    /// Live slots currently hosting a DP copy (per-query `Done` fan-out).
    fn live_dp_slots(&self, placement: &Placement, dp_hosts: &[u16]) -> Vec<u16> {
        let cs = self.state.lock().unwrap();
        dp_hosts.iter().flat_map(|&n| cs.live_slots_of(placement, n)).collect()
    }

    /// The slots an emission for logical `node` must reach. Query-path
    /// messages route to exactly one live replica (the same one every
    /// sender would pick — see `net::cluster::replica`); write-path
    /// messages fan to *all* replicas and require the full set live, or
    /// the copies would silently diverge.
    fn targets(
        &self,
        placement: &Placement,
        node: u16,
        msg: &Msg,
    ) -> std::result::Result<Vec<u16>, String> {
        let cs = self.state.lock().unwrap();
        let live = cs.live_slots_of(placement, node);
        if live.is_empty() {
            return Err(format!("logical node {node} has no live replica"));
        }
        match msg {
            Msg::Query { qid, v, .. } | Msg::CandidateReq { qid, v, .. } => {
                Ok(vec![pick_slot(self.route, &live, *qid, v)])
            }
            _ => {
                if live.len() != placement.replication {
                    return Err(format!(
                        "write to node {node} with {}/{} replicas live; heal the dead \
                         replica before writing",
                        live.len(),
                        placement.replication
                    ));
                }
                Ok(live)
            }
        }
    }
}

/// Encode the current membership table as a broadcast-ready frame. Must be
/// called under the same lock that mutated the table, so every broadcast
/// carries a consistent (epoch, live, addrs) snapshot.
fn membership_frame(cs: &ClusterState) -> Vec<u8> {
    let table: Vec<(bool, String)> =
        cs.live.iter().copied().zip(cs.addrs.iter().cloned()).collect();
    wire::encode_frame(FrameKind::Membership, &wire::encode_membership(cs.epoch, &table))
}

/// Per-stream retarget bookkeeping. `dispatch_ts` and `origin` are keyed
/// by ORIGINAL qid (latency spans every retry; the origin message is what
/// gets re-dispatched), `retry_of` maps minted retry qids back to their
/// original, `cancelled` suppresses completions of superseded attempts,
/// and `inflight_qids` (originals and retries alike) is exactly the set a
/// death handler must re-dispatch.
#[derive(Default)]
struct Retarget {
    dispatch_ts: HashMap<u32, Instant>,
    origin: HashMap<u32, Msg>,
    retry_of: HashMap<u32, u32>,
    cancelled: HashSet<u32>,
    inflight_qids: HashSet<u32>,
    next_retry: u32,
    retargeted: u64,
}

impl Retarget {
    fn new() -> Retarget {
        Retarget { next_retry: RETRY_BASE, ..Default::default() }
    }
}

struct Session {
    peers: Vec<PeerConn>,
    ev_rx: Receiver<DriverEv>,
    /// Sender half of `ev_rx` (bounded, `net.queue_frames`) — streaming
    /// runs clone it for their ingress.
    ev_tx: SyncSender<DriverEv>,
    placement: Placement,
    /// Worker nodes hosting at least one DP copy (get per-query `Done`s).
    dp_hosts: Vec<u16>,
    flush_seq: u32,
    /// A streaming run currently owns `peers`/`ev_rx`; phase runs,
    /// snapshots and shutdown must wait for its `finish`.
    stream_open: bool,
    /// A streaming run died on this executor. The returned connection
    /// state may hold stale events (undrained ingress, in-flight frames
    /// for cancelled queries), so everything except `shutdown` refuses to
    /// touch it — relaunch the `NetSession` instead of risking a poisoned
    /// phase on a half-dead fleet.
    broken: bool,
    /// Shared membership/epoch view + replica-routing knobs.
    ctl: ClusterCtl,
}

/// An [`Executor`] that runs BI/DP stages on remote worker processes. The
/// local `bis`/`dps` handlers in [`StageHandlers`] are intentionally not
/// driven — that state lives (and persists across phases) in the workers;
/// fetch it with [`NetSession::fetch_state`].
pub struct SocketExecutor {
    inner: Mutex<Session>,
}

impl Executor for SocketExecutor {
    fn run(
        &self,
        placement: &Placement,
        stages: StageHandlers<'_>,
        workload: Workload<'_>,
    ) -> ExecReport {
        let mut s = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        match s.run_phase(placement, stages, workload) {
            Ok(report) => report,
            // Mirror the threaded executor: a dead stage (here: worker)
            // resurfaces loudly instead of wedging the admission loop.
            Err(e) => panic!("socket phase failed: {e}"),
        }
    }

    /// A streaming run over the live worker fleet: connections stay hot,
    /// submissions are admitted the moment they arrive, and the
    /// `FlushReq`/`FlushAck` barrier happens once per stream (at `finish`)
    /// instead of once per pump. The admission loop moves onto a dedicated
    /// thread that owns the peer connections for the run's lifetime.
    fn open_stream<'e>(
        &'e self,
        placement: &Placement,
        stages: StageHandlers<'static>,
        cfg: StreamConfig,
    ) -> Box<dyn StreamRun + 'e> {
        let mut s = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if s.broken {
            panic!("a previous streaming run on this socket executor failed; relaunch the NetSession");
        }
        if s.stream_open {
            panic!("a streaming run is already open on this socket executor");
        }
        if s.peers.len() + 1 != s.placement.total_nodes() {
            panic!(
                "socket executor holds {}/{} worker connections (a streaming run died \
                 without returning them); relaunch the NetSession",
                s.peers.len(),
                s.placement.total_nodes() - 1
            );
        }
        if *placement != s.placement {
            panic!("stream placement differs from the placement workers were launched with");
        }
        let peers = std::mem::take(&mut s.peers);
        let ev_rx = std::mem::replace(&mut s.ev_rx, mpsc::sync_channel(1).1);
        let ev_tx = s.ev_tx.clone();
        let dp_hosts = s.dp_hosts.clone();
        let flush_seq = s.flush_seq;
        let ctl = s.ctl.clone();
        s.stream_open = true;
        drop(s);

        let StageHandlers { head, bis, dps, ags } = stages;
        drop(bis); // BI/DP state lives in the workers, not behind these
        drop(dps);

        let gate = Arc::new(StreamGate::new(cfg.pending_cap));
        let (eg_tx, eg_rx) = mpsc::channel::<StreamCompletion>();
        let g = gate.clone();
        let p = placement.clone();
        let admission = std::thread::spawn(move || {
            socket_stream_loop(
                head, ags, peers, ev_rx, eg_tx, g, p, dp_hosts, cfg, flush_seq, ctl,
            )
        });
        Box::new(SocketStreamRun {
            exec: self,
            ev_tx,
            gate,
            egress_rx: eg_rx,
            admission: Some(admission),
        })
    }
}

/// What the socket streaming admission thread hands back at join: the
/// run's accounting plus the connection state it borrowed from the
/// executor, restored by [`SocketStreamRun::finish`].
struct SocketStreamJoin {
    peers: Vec<PeerConn>,
    ev_rx: Receiver<DriverEv>,
    meter: TrafficMeter,
    work: Vec<(StageKind, u16, WorkStats)>,
    flush_seq: u32,
    /// Queries cancelled and re-dispatched to surviving replicas after a
    /// mid-stream worker death.
    retargeted: u64,
    error: Option<String>,
}

/// The socket transport's [`StreamRun`] handle.
pub struct SocketStreamRun<'e> {
    exec: &'e SocketExecutor,
    ev_tx: SyncSender<DriverEv>,
    gate: Arc<StreamGate>,
    egress_rx: Receiver<StreamCompletion>,
    admission: Option<std::thread::JoinHandle<SocketStreamJoin>>,
}

/// Enqueue `Finish` on the bounded event queue without ever wedging: if
/// the admission thread already exited (error path — nobody drains the
/// queue anymore), skip the send and let the caller join directly.
fn send_finish(
    ev_tx: &SyncSender<DriverEv>,
    admission: &Option<std::thread::JoinHandle<SocketStreamJoin>>,
) {
    loop {
        match admission {
            None => return,
            Some(h) if h.is_finished() => return,
            Some(_) => {}
        }
        match ev_tx.try_send(DriverEv::Finish) {
            Ok(()) => return,
            Err(TrySendError::Full(_)) => std::thread::sleep(EV_FULL_TICK),
            Err(TrySendError::Disconnected(_)) => return,
        }
    }
}

impl SocketStreamRun<'_> {
    /// True when the admission thread can no longer drain the queue (gone
    /// or already exited) — continuing to wait on it would wedge.
    fn admission_gone(&self) -> bool {
        self.admission.as_ref().map(|h| h.is_finished()).unwrap_or(true)
    }

    /// Enqueue one ingress event on the bounded driver queue. Parks in
    /// short ticks while wire fan-in holds the queue full (backpressure —
    /// the queue is shared with the worker readers) and dies loudly if
    /// the admission thread is gone instead of blocking forever. Only the
    /// *blocking* [`StreamRun::submit`] path uses this; `try_submit` stays
    /// genuinely non-blocking (a full queue is a decline there).
    fn send_ingress(&mut self, msg: Msg) {
        let mut ev = DriverEv::Ingress(msg);
        loop {
            match self.ev_tx.try_send(ev) {
                Ok(()) => return,
                Err(TrySendError::Full(back)) => {
                    if self.admission_gone() {
                        self.die();
                    }
                    ev = back;
                    std::thread::sleep(EV_FULL_TICK);
                }
                Err(TrySendError::Disconnected(_)) => self.die(),
            }
        }
    }

    /// Wind the admission thread down and hand the connections back to the
    /// executor, returning the run's accounting (+ typed failure, if any).
    #[allow(clippy::type_complexity)]
    fn wind_down(
        &mut self,
    ) -> (TrafficMeter, Vec<(StageKind, u16, WorkStats)>, u64, Option<String>) {
        send_finish(&self.ev_tx, &self.admission);
        let handle = self.admission.take().expect("socket stream already wound down");
        let join = handle
            .join()
            .unwrap_or_else(|p| std::panic::resume_unwind(p));
        let mut s = self.exec.inner.lock().unwrap_or_else(|p| p.into_inner());
        s.peers = join.peers;
        s.ev_rx = join.ev_rx;
        s.flush_seq = join.flush_seq;
        s.stream_open = false;
        // A died stream can leave stale events (undrained ingress,
        // frames for cancelled queries) in the restored channel: refuse
        // further use instead of poisoning the next phase.
        s.broken |= join.error.is_some();
        (join.meter, join.work, join.retargeted, join.error)
    }

    fn die(&mut self) -> ! {
        if self.admission.is_some() {
            let (_, _, _, error) = self.wind_down();
            if let Some(e) = error {
                panic!("socket stream failed: {e}");
            }
        }
        panic!("socket stream run died");
    }
}

impl StreamRun for SocketStreamRun<'_> {
    fn submit(&mut self, msg: Msg) {
        let gated = msg.qid().is_some();
        if gated && !self.gate.acquire() {
            self.die();
        }
        self.send_ingress(msg);
    }

    fn try_submit(&mut self, msg: Msg) -> std::result::Result<(), Msg> {
        let gated = msg.qid().is_some();
        if gated {
            match self.gate.try_acquire() {
                Ok(true) => {}
                Ok(false) => return Err(msg),
                Err(()) => self.die(),
            }
        }
        // Genuinely non-blocking: a full driver queue is a decline, same
        // as a full backpressure window — callers (the session's
        // try_submit_one runs under the session mutex) must never be
        // parked here, or non-blocking calls would stall behind us.
        match self.ev_tx.try_send(DriverEv::Ingress(msg)) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(ev)) => {
                if gated {
                    self.gate.release();
                }
                if self.admission_gone() {
                    self.die();
                }
                match ev {
                    DriverEv::Ingress(m) => Err(m),
                    _ => unreachable!("try_send returned a different event"),
                }
            }
            Err(TrySendError::Disconnected(_)) => self.die(),
        }
    }

    fn can_submit(&self) -> bool {
        self.gate.has_room()
    }

    fn recv(&mut self, timeout: Duration) -> Option<StreamCompletion> {
        match self.egress_rx.recv_timeout(timeout) {
            Ok(c) => Some(c),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => self.die(),
        }
    }

    fn try_recv(&mut self) -> Option<StreamCompletion> {
        match self.egress_rx.try_recv() {
            Ok(c) => Some(c),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => self.die(),
        }
    }

    fn finish(mut self: Box<Self>) -> StreamReport {
        let (meter, work, retargeted, error) = self.wind_down();
        if let Some(e) = error {
            panic!("socket stream failed: {e}");
        }
        let mut unclaimed = Vec::new();
        while let Ok(c) = self.egress_rx.try_recv() {
            unclaimed.push(c);
        }
        StreamReport { unclaimed, meter, work, retargeted }
    }
}

impl Drop for SocketStreamRun<'_> {
    fn drop(&mut self) {
        // Dropped without `finish` (caller unwound): wind down and restore
        // the connections without panicking — aborting during an unwind
        // would take the whole process down.
        send_finish(&self.ev_tx, &self.admission);
        if let Some(handle) = self.admission.take() {
            match handle.join() {
                Ok(join) => {
                    let mut s = self.exec.inner.lock().unwrap_or_else(|p| p.into_inner());
                    s.peers = join.peers;
                    s.ev_rx = join.ev_rx;
                    s.flush_seq = join.flush_seq;
                    s.stream_open = false;
                    s.broken |= join.error.is_some();
                }
                Err(_) => {
                    // The admission thread panicked and took the worker
                    // connections down with it. Clear the stream flag so
                    // the executor fails on the lost-connection guard
                    // (the real story) instead of wedging forever behind
                    // a misleading "stream open" error.
                    eprintln!(
                        "[parlsh] socket stream admission thread panicked; \
                         worker connections lost"
                    );
                    let mut s = self.exec.inner.lock().unwrap_or_else(|p| p.into_inner());
                    s.stream_open = false;
                    s.broken = true;
                }
            }
        }
    }
}

/// The socket streaming admission loop (its own thread): the streaming
/// rendition of [`Session::run_phase`] — closed-loop windowed admission,
/// deferred ingress, local AG delivery, per-completion `Done` acks and
/// gate releases — with the worker-meter barrier run once at the end.
///
/// This loop is also the cluster's mid-stream failure detector: while
/// queries are in flight it wakes every `net.heartbeat_ms` to ping live
/// slots, and a slot that drops its connection, fails a send, or goes
/// silent for [`HEARTBEAT_MISSES`] intervals is marked dead and its
/// in-flight queries are cancelled and re-dispatched to surviving
/// replicas ([`replica_death`]). The stream only errors when a logical
/// node loses its *last* replica.
#[allow(clippy::too_many_arguments)]
fn socket_stream_loop(
    mut head: Box<dyn StageHandler>,
    mut ags: Vec<Box<dyn StageHandler>>,
    mut peers: Vec<PeerConn>,
    ev_rx: Receiver<DriverEv>,
    egress: mpsc::Sender<StreamCompletion>,
    gate: Arc<StreamGate>,
    placement: Placement,
    dp_hosts: Vec<u16>,
    cfg: StreamConfig,
    mut flush_seq: u32,
    ctl: ClusterCtl,
) -> SocketStreamJoin {
    // Opens the gate on every exit path so blocked submitters never hang
    // on a dead run.
    let _gg = GateGuard(gate.clone());
    let mut meter = TrafficMeter::new(cfg.agg_bytes);
    meter.header_bytes = 0; // frames carry their real header in len
    let head_node = placement.head_node;
    let mut emitted: Vec<(Dest, Msg)> = Vec::new();
    let mut pending: VecDeque<Msg> = VecDeque::new();
    let mut local_q: VecDeque<(Dest, Msg)> = VecDeque::new();
    let mut comps: Vec<QueryResult> = Vec::new();
    let mut rt = Retarget::new();
    let mut in_flight = 0usize;
    let mut finishing = false;
    let mut error: Option<String> = None;
    let mut last_heard: Vec<Instant> = vec![Instant::now(); peers.len()];
    let mut last_progress = Instant::now();

    'run: loop {
        // Admit deferred ingress while the window allows (non-query items
        // are never windowed — same policy as the phase run).
        while error.is_none() {
            let next_is_query = match pending.front() {
                None => break,
                Some(m) => m.qid().is_some(),
            };
            if next_is_query && cfg.window != 0 && in_flight >= cfg.window {
                break;
            }
            let item = pending.pop_front().expect("peeked non-empty");
            let item_qid = item.qid();
            if let Some(qid) = item_qid {
                if qid < RETRY_BASE {
                    rt.dispatch_ts.insert(qid, Instant::now());
                    rt.origin.insert(qid, item.clone());
                }
                rt.inflight_qids.insert(qid);
                in_flight += 1;
            }
            head.on_msg(item, &mut emitted);
            let mut died: Option<(u16, String)> = None;
            for (dest, msg) in emitted.drain(..) {
                let node = placement.node_of(dest.stage, dest.copy);
                if node == head_node {
                    meter.send(head_node, head_node, 0);
                    local_q.push_back((dest, msg));
                    continue;
                }
                let slots = match ctl.targets(&placement, node, &msg) {
                    Ok(s) => s,
                    Err(e) => {
                        error = Some(e);
                        break;
                    }
                };
                let frame = wire::stage_frame(dest, &msg);
                for &slot in &slots {
                    meter.send(head_node, slot, frame.len());
                    if let Err(e) = peers[slot as usize].send(&frame) {
                        died = Some((slot, format!("send failed: {e}")));
                        break;
                    }
                }
                if died.is_some() {
                    break;
                }
            }
            // Any emissions left after a mid-item break belong to a query
            // that is about to be cancelled+retried (or to a failed run):
            // dropping them is safe for queries, but a half-sent *write*
            // cannot be recovered — surviving replicas may have missed
            // frames too.
            emitted.clear();
            if let Some((slot, why)) = died {
                if item_qid.is_none() {
                    error = Some(format!(
                        "worker slot {slot} died during a streamed write ({why}); \
                         replica consistency cannot be guaranteed"
                    ));
                } else if let Err(e) = replica_death(
                    slot, &why, &ctl, &placement, &mut peers, &dp_hosts, &mut pending,
                    &mut rt, &mut in_flight, &mut ags,
                ) {
                    error = Some(e);
                }
            }
            if error.is_some() {
                break;
            }
            if let Err(e) = drain_local_stream(
                &mut local_q, &mut ags, &mut comps, &mut rt, &mut in_flight, &mut peers,
                &ctl, &placement, &dp_hosts, &gate, &egress,
            ) {
                error = Some(e);
            }
        }
        if error.is_some() || (finishing && pending.is_empty() && in_flight == 0) {
            break 'run;
        }
        // Everything queued must reach the wire before blocking, or the
        // closed loop deadlocks on a buffered frame. Only live slots are
        // flushed — a dead slot's stale connection would just error.
        {
            let live = ctl.live_mask();
            let mut flush_died: Option<(u16, String)> = None;
            for (slot, p) in peers.iter_mut().enumerate() {
                if !live[slot] {
                    continue;
                }
                if let Err(e) = p.flush() {
                    flush_died = Some((slot as u16, format!("flush failed: {e}")));
                    break;
                }
            }
            if let Some((slot, why)) = flush_died {
                if let Err(e) = replica_death(
                    slot, &why, &ctl, &placement, &mut peers, &dp_hosts, &mut pending,
                    &mut rt, &mut in_flight, &mut ags,
                ) {
                    error = Some(e);
                }
                continue 'run;
            }
        }
        // Idle is normal on a long-lived stream, so both the stall clock
        // and the heartbeat only run while queries are actually in flight.
        let ev = if in_flight > 0 {
            match ev_rx.recv_timeout(ctl.heartbeat) {
                Ok(ev) => Some(ev),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => {
                    error = Some("all worker readers exited".into());
                    continue 'run;
                }
            }
        } else {
            match ev_rx.recv() {
                Ok(ev) => Some(ev),
                Err(_) => {
                    error = Some("all worker readers exited".into());
                    continue 'run;
                }
            }
        };
        let Some(ev) = ev else {
            // Heartbeat tick: nothing arrived for a full interval. Declare
            // slots dead after HEARTBEAT_MISSES silent intervals, ping the
            // rest, and keep the overall stall clock from the old loop.
            if last_progress.elapsed() >= PHASE_STALL_TIMEOUT {
                error = Some(format!(
                    "stream stalled: {in_flight} queries in flight after {}s of silence",
                    PHASE_STALL_TIMEOUT.as_secs()
                ));
                continue 'run;
            }
            let live = ctl.live_mask();
            let ping = wire::encode_frame(FrameKind::Ping, &[]);
            let mut silent: Vec<u16> = Vec::new();
            for (slot, p) in peers.iter_mut().enumerate() {
                if !live[slot] {
                    continue;
                }
                if last_heard[slot].elapsed() > ctl.heartbeat * HEARTBEAT_MISSES {
                    silent.push(slot as u16);
                } else if p.send_now(&ping).is_err() {
                    silent.push(slot as u16);
                }
            }
            for slot in silent {
                if let Err(e) = replica_death(
                    slot, "heartbeat silence", &ctl, &placement, &mut peers, &dp_hosts,
                    &mut pending, &mut rt, &mut in_flight, &mut ags,
                ) {
                    error = Some(e);
                    break;
                }
            }
            continue 'run;
        };
        last_progress = Instant::now();
        if let Some(from) = ev_from(&ev) {
            if let Some(t) = last_heard.get_mut(from as usize) {
                *t = Instant::now();
            }
        }
        match ev {
            DriverEv::Ingress(m) => pending.push_back(m),
            DriverEv::Finish => finishing = true,
            DriverEv::Msg { dest, msg, .. } => {
                local_q.push_back((dest, msg));
                if let Err(e) = drain_local_stream(
                    &mut local_q, &mut ags, &mut comps, &mut rt, &mut in_flight,
                    &mut peers, &ctl, &placement, &dp_hosts, &gate, &egress,
                ) {
                    error = Some(e);
                }
            }
            DriverEv::Pong { .. } => {} // heartbeat reply; clock already reset
            DriverEv::Stopped { from, reason } => {
                if let Err(e) = replica_death(
                    from, &format!("stopped: {reason}"), &ctl, &placement, &mut peers,
                    &dp_hosts, &mut pending, &mut rt, &mut in_flight, &mut ags,
                ) {
                    error = Some(e);
                }
            }
            DriverEv::Closed { from, err } => {
                if let Err(e) = replica_death(
                    from, &format!("connection lost: {err}"), &ctl, &placement,
                    &mut peers, &dp_hosts, &mut pending, &mut rt, &mut in_flight,
                    &mut ags,
                ) {
                    error = Some(e);
                }
            }
            _ => error = Some("unexpected control frame mid-stream".into()),
        }
    }

    // Quiescence barrier: collect every live worker's meter and per-copy
    // work exactly once per stream — not once per pump. Skipped if the
    // run already died.
    let mut work: Vec<(StageKind, u16, WorkStats)> = Vec::new();
    if error.is_none() {
        flush_seq += 1;
        let req = wire::encode_frame(FrameKind::FlushReq, &wire::encode_qid(flush_seq));
        let live = ctl.live_mask();
        let mut expect = 0usize;
        for (slot, p) in peers.iter_mut().enumerate() {
            if !live[slot] {
                continue;
            }
            if let Err(e) = p.send_now(&req) {
                error = Some(format!("barrier send to slot {slot}: {e}"));
                break;
            }
            expect += 1;
        }
        let mut acks = 0usize;
        while error.is_none() && acks < expect {
            match ev_rx.recv_timeout(CONTROL_TIMEOUT) {
                Ok(DriverEv::FlushAck { seq, meter: m, work: w, from }) => {
                    if seq != flush_seq {
                        error = Some(format!(
                            "worker {from} acked barrier {seq}, expected {flush_seq}"
                        ));
                    } else {
                        meter.merge(&m);
                        work.extend(w);
                        acks += 1;
                    }
                }
                Ok(DriverEv::Stopped { from, reason }) => {
                    if live[from as usize] {
                        error =
                            Some(format!("worker {from} stopped at barrier: {reason}"));
                    }
                }
                Ok(DriverEv::Closed { from, err }) => {
                    if live[from as usize] {
                        error = Some(format!(
                            "worker {from} connection lost at barrier: {err}"
                        ));
                    }
                }
                // Straggler stage frames can only belong to queries that
                // were cancelled by a retarget (every live query completed
                // before the barrier) — tolerate them exactly then.
                Ok(DriverEv::Msg { .. }) if rt.retargeted > 0 => {}
                // late chatter from the run handle; harmless at a barrier
                Ok(DriverEv::Ingress(_)) | Ok(DriverEv::Finish)
                | Ok(DriverEv::Pong { .. }) => {}
                Ok(_) => error = Some("unexpected frame at stream barrier".into()),
                Err(e) => error = Some(format!("stream barrier: {e}")),
            }
        }
    }
    meter.flush();
    SocketStreamJoin {
        peers,
        ev_rx,
        meter,
        work,
        flush_seq,
        retargeted: rt.retargeted,
        error,
    }
}

/// Mid-stream death of one worker slot. Marks it dead (idempotent — the
/// heartbeat and the reader's `Closed` often both report the same crash),
/// broadcasts the shrunk membership so worker→worker routing agrees with
/// ours before any retried traffic arrives (per-connection FIFO), then
/// cancels every in-flight query and re-dispatches it under a fresh retry
/// qid. The whole query is the unit of recovery: its partial state
/// (BI probes routed, DP dedup entries) may have died with the replica,
/// so surviving partial work is torn down (`Done`) and suppressed at
/// completion rather than merged.
///
/// Errors (→ stream failure) only when the dead slot was the last live
/// replica of its logical node.
#[allow(clippy::too_many_arguments)]
fn replica_death(
    slot: u16,
    why: &str,
    ctl: &ClusterCtl,
    placement: &Placement,
    peers: &mut [PeerConn],
    dp_hosts: &[u16],
    pending: &mut VecDeque<Msg>,
    rt: &mut Retarget,
    in_flight: &mut usize,
    ags: &mut [Box<dyn StageHandler>],
) -> std::result::Result<(), String> {
    let (mem_frame, live_dp, live) = {
        let mut cs = ctl.state.lock().unwrap();
        if !cs.live[slot as usize] {
            return Ok(()); // already handled under another signal
        }
        cs.mark_dead(slot);
        let node = placement.node_of_slot(slot);
        if !cs.node_has_live(placement, node) {
            return Err(format!(
                "worker slot {slot} died ({why}) and logical node {node} has no live \
                 replica left"
            ));
        }
        let dp: Vec<u16> =
            dp_hosts.iter().flat_map(|&n| cs.live_slots_of(placement, n)).collect();
        (membership_frame(&cs), dp, cs.live.clone())
    };
    eprintln!(
        "[parlsh] worker slot {slot} died mid-stream ({why}); retargeting {} in-flight \
         queries to surviving replicas",
        rt.inflight_qids.len()
    );
    for (sl, p) in peers.iter_mut().enumerate() {
        if live[sl] {
            // a failure here surfaces as that peer's own death event
            let _ = p.send_now(&mem_frame);
        }
    }
    let stale: Vec<u32> = rt.inflight_qids.drain().collect();
    for qid in stale {
        rt.cancelled.insert(qid);
        *in_flight = in_flight.saturating_sub(1);
        // Tear down any per-query dedup state the survivors hold for the
        // cancelled attempt.
        let done = wire::encode_frame(FrameKind::Done, &wire::encode_qid(qid));
        for &s in &live_dp {
            let _ = peers[s as usize].send(&done);
        }
        // Purge the local AG's partial reduction under the cancelled qid:
        // a later run may legally reuse it, and a stale entry would trip
        // the duplicate-QueryMeta guard. No-op on copies that never saw it.
        for ag in ags.iter_mut() {
            ag.abort_query(qid);
        }
        let orig = rt.retry_of.remove(&qid).unwrap_or(qid);
        let Some(seed) = rt.origin.get(&orig) else {
            return Err(format!("no origin message recorded for in-flight query {orig}"));
        };
        let rq = rt.next_retry;
        rt.next_retry += 1;
        let mut retry = seed.clone();
        match &mut retry {
            Msg::QueryVec { qid, .. }
            | Msg::Query { qid, .. }
            | Msg::CandidateReq { qid, .. } => *qid = rq,
            other => {
                return Err(format!(
                    "in-flight item for query {orig} is not retryable: {other:?}"
                ))
            }
        }
        rt.retry_of.insert(rq, orig);
        // Front of the queue: retries resume ahead of new ingress, so the
        // closed-loop window drains in roughly the original order.
        pending.push_front(retry);
        rt.retargeted += 1;
    }
    Ok(())
}

/// Deliver queued head-node messages on a streaming run and handle
/// completions: latency from the per-qid dispatch stamp, `Done` acks to
/// every live DP slot, a gate release, and the completion onto the egress.
/// Retry-aware: a retry qid completes under its *original* id (latency
/// spans the whole retry), and completions of cancelled attempts are
/// swallowed — their replacement owns the gate slot and the egress.
#[allow(clippy::too_many_arguments)]
fn drain_local_stream(
    local_q: &mut VecDeque<(Dest, Msg)>,
    ags: &mut [Box<dyn StageHandler>],
    comps: &mut Vec<QueryResult>,
    rt: &mut Retarget,
    in_flight: &mut usize,
    peers: &mut [PeerConn],
    ctl: &ClusterCtl,
    placement: &Placement,
    dp_hosts: &[u16],
    gate: &StreamGate,
    egress: &mpsc::Sender<StreamCompletion>,
) -> std::result::Result<(), String> {
    let mut emitted: Vec<(Dest, Msg)> = Vec::new();
    while let Some((dest, msg)) = local_q.pop_front() {
        if dest.stage != StageKind::Ag {
            return Err(format!("{:?} message addressed to the head node", dest.stage));
        }
        let ag = match ags.get_mut(dest.copy as usize) {
            Some(a) => a,
            None => return Err(format!("no AG copy {}", dest.copy)),
        };
        ag.on_msg(msg, &mut emitted);
        debug_assert!(emitted.is_empty(), "AG emitted a message");
        emitted.clear();
        ag.take_completions(comps);
        for (qid, hits) in comps.drain(..) {
            if rt.cancelled.remove(&qid) {
                // A cancelled attempt limped home anyway (e.g. only a DP
                // replica died and the BI path still finished): swallow
                // it — its replacement retry is the query of record.
                continue;
            }
            rt.inflight_qids.remove(&qid);
            let orig = rt.retry_of.remove(&qid).unwrap_or(qid);
            let secs = rt
                .dispatch_ts
                .remove(&orig)
                .map(|t| t.elapsed().as_secs_f64())
                .unwrap_or(0.0);
            rt.origin.remove(&orig);
            *in_flight = in_flight.saturating_sub(1);
            // The completion ack: closes the inflight loop and drops the
            // remote per-query dedup state. Control — never metered. A
            // failing send surfaces as that peer's own death event.
            let done = wire::encode_frame(FrameKind::Done, &wire::encode_qid(qid));
            for slot in ctl.live_dp_slots(placement, dp_hosts) {
                let _ = peers[slot as usize].send(&done);
            }
            gate.release();
            let _ = egress.send(StreamCompletion { qid: orig, hits, secs });
        }
    }
    Ok(())
}

impl Session {
    fn run_phase(
        &mut self,
        placement: &Placement,
        stages: StageHandlers<'_>,
        workload: Workload<'_>,
    ) -> Result<ExecReport> {
        if self.broken {
            bail!("a previous streaming run on this socket executor failed; relaunch the NetSession");
        }
        if self.stream_open {
            bail!("a streaming run is open on this socket executor; finish it before a phase run");
        }
        if self.peers.len() + 1 != self.placement.total_nodes() {
            bail!(
                "socket executor holds {}/{} worker connections (a streaming run died \
                 without returning them); relaunch the NetSession",
                self.peers.len(),
                self.placement.total_nodes() - 1
            );
        }
        if *placement != self.placement {
            bail!("phase placement differs from the placement workers were launched with");
        }
        let Session { peers, ev_rx, dp_hosts, flush_seq, ctl, .. } = self;
        let head = placement.head_node;
        let n_queries = workload.n_queries;
        let window = workload.window;

        let StageHandlers { head: mut head_stage, bis, dps, mut ags } = stages;
        drop(bis); // BI/DP state lives in the workers, not behind these
        drop(dps);

        let mut meter = TrafficMeter::new(workload.agg_bytes);
        meter.header_bytes = 0; // frames carry their real header in len
        let mut results: Vec<Vec<(f32, u32)>> = vec![Vec::new(); n_queries];
        let mut per_query_secs = vec![0f64; n_queries];
        let mut dispatch_ts: Vec<Instant> = vec![Instant::now(); n_queries];
        let mut local_q: VecDeque<(Dest, Msg)> = VecDeque::new();
        let mut emitted: Vec<(Dest, Msg)> = Vec::new();
        let mut comps: Vec<QueryResult> = Vec::new();
        let mut completed = 0usize;
        let mut in_flight = 0usize;
        let mut items = workload.items.peekable();
        let mut items_done = false;
        // Any write admitted (index blocks, store batches — items without
        // a qid) advances the session epoch at the end of the phase.
        let mut wrote = false;

        loop {
            // Admit while the window allows; items without a qid (index
            // blocks) are never windowed — same policy as the threaded
            // executor.
            while !items_done {
                let next_is_query = match items.peek() {
                    None => {
                        items_done = true;
                        break;
                    }
                    Some(m) => m.qid().is_some(),
                };
                if next_is_query && window != 0 && in_flight >= window {
                    break;
                }
                let item = items.next().expect("peeked non-empty");
                let item_qid = item.qid();
                if item_qid.is_none() {
                    wrote = true;
                }
                head_stage.on_msg(item, &mut emitted);
                if let Some(qid) = item_qid {
                    dispatch_ts[qid as usize] = Instant::now();
                    in_flight += 1;
                }
                for (dest, msg) in emitted.drain(..) {
                    let node = placement.node_of(dest.stage, dest.copy);
                    if node == head {
                        meter.send(head, head, 0);
                        local_q.push_back((dest, msg));
                        continue;
                    }
                    // Writes fan to every replica slot (all must be live);
                    // queries route to one live replica. A phase run does
                    // not retarget — a death here fails the phase loudly.
                    let slots = ctl.targets(placement, node, &msg).map_err(|e| anyhow!(e))?;
                    let frame = wire::stage_frame(dest, &msg);
                    for &slot in &slots {
                        meter.send(head, slot, frame.len());
                        peers[slot as usize].send(&frame)?;
                    }
                }
                drain_local(
                    &mut local_q,
                    &mut ags,
                    &mut comps,
                    &mut results,
                    &mut per_query_secs,
                    &dispatch_ts,
                    &mut completed,
                    &mut in_flight,
                    peers,
                    ctl,
                    placement,
                    dp_hosts,
                )?;
            }
            if items_done && completed >= n_queries {
                break;
            }
            // Block for remote events — but only after everything queued
            // reached the wire, or the closed loop deadlocks. Dead slots'
            // stale connections are skipped.
            {
                let live = ctl.live_mask();
                for (slot, p) in peers.iter_mut().enumerate() {
                    if live[slot] {
                        p.flush()?;
                    }
                }
            }
            match ev_rx.recv_timeout(PHASE_STALL_TIMEOUT) {
                Ok(DriverEv::Msg { dest, msg, .. }) => {
                    local_q.push_back((dest, msg));
                    drain_local(
                        &mut local_q,
                        &mut ags,
                        &mut comps,
                        &mut results,
                        &mut per_query_secs,
                        &dispatch_ts,
                        &mut completed,
                        &mut in_flight,
                        peers,
                        ctl,
                        placement,
                        dp_hosts,
                    )?;
                }
                // late heartbeat replies from a preceding stream
                Ok(DriverEv::Pong { .. }) => {}
                Ok(DriverEv::Stopped { from, reason }) => {
                    if ctl.is_live(from) {
                        bail!("worker {from} stopped mid-phase: {reason}")
                    }
                }
                Ok(DriverEv::Closed { from, err }) => {
                    if ctl.is_live(from) {
                        bail!("worker {from} connection lost mid-phase: {err}")
                    }
                }
                Ok(_) => bail!("unexpected control frame mid-phase"),
                Err(RecvTimeoutError::Timeout) => bail!(
                    "phase stalled: {completed}/{n_queries} queries after {}s of silence",
                    PHASE_STALL_TIMEOUT.as_secs()
                ),
                Err(RecvTimeoutError::Disconnected) => bail!("all worker readers exited"),
            }
        }

        // Phase barrier: collect every live worker's real bytes-on-wire
        // meter plus its per-copy work counters (so the report's work
        // accounting covers the remote BI/DP copies, not just the head).
        *flush_seq += 1;
        let seq = *flush_seq;
        let req = wire::encode_frame(FrameKind::FlushReq, &wire::encode_qid(seq));
        let live = ctl.live_mask();
        let mut expect = 0usize;
        for (slot, p) in peers.iter_mut().enumerate() {
            if live[slot] {
                p.send_now(&req)?;
                expect += 1;
            }
        }
        meter.flush();
        let mut remote_work: Vec<(StageKind, u16, WorkStats)> = Vec::new();
        let mut acks = 0usize;
        while acks < expect {
            match ev_rx.recv_timeout(CONTROL_TIMEOUT) {
                Ok(DriverEv::FlushAck { seq: s, meter: m, work, from }) => {
                    if s != seq {
                        bail!("worker {from} acked barrier {s}, expected {seq}");
                    }
                    meter.merge(&m);
                    remote_work.extend(work);
                    acks += 1;
                }
                Ok(DriverEv::Pong { .. }) => {}
                Ok(DriverEv::Stopped { from, reason }) => {
                    if live[from as usize] {
                        bail!("worker {from} stopped at barrier: {reason}")
                    }
                }
                Ok(DriverEv::Closed { from, err }) => {
                    if live[from as usize] {
                        bail!("worker {from} connection lost at barrier: {err}")
                    }
                }
                Ok(_) => bail!("unexpected frame at phase barrier"),
                Err(e) => bail!("phase barrier: {e}"),
            }
        }
        // A completed write phase advances the epoch: every replica now
        // holds the new state, and any worker that rejoins later must
        // either present a shard at this exact epoch or be restored from
        // a live sibling. Broadcast so workers answer `Ping`/rejoin
        // validation with the current value.
        if wrote {
            let (frame, live) = {
                let mut cs = ctl.state.lock().unwrap();
                cs.epoch += 1;
                (membership_frame(&cs), cs.live.clone())
            };
            for (slot, p) in peers.iter_mut().enumerate() {
                if live[slot] {
                    p.send_now(&frame)?;
                }
            }
        }
        Ok(ExecReport { results, per_query_secs, meter, work: remote_work })
    }
}

/// Deliver queued head-node messages (always AG — the head hosts no BI/DP
/// copy) and handle completions: record result + latency, shrink the
/// admission window, and fan the `Done` ack to every DP-hosting worker.
#[allow(clippy::too_many_arguments)]
fn drain_local(
    local_q: &mut VecDeque<(Dest, Msg)>,
    ags: &mut [Box<dyn StageHandler + '_>],
    comps: &mut Vec<QueryResult>,
    results: &mut [Vec<(f32, u32)>],
    per_query_secs: &mut [f64],
    dispatch_ts: &[Instant],
    completed: &mut usize,
    in_flight: &mut usize,
    peers: &mut [PeerConn],
    ctl: &ClusterCtl,
    placement: &Placement,
    dp_hosts: &[u16],
) -> Result<()> {
    let mut emitted: Vec<(Dest, Msg)> = Vec::new();
    while let Some((dest, msg)) = local_q.pop_front() {
        if dest.stage != StageKind::Ag {
            bail!("{:?} message addressed to the head node", dest.stage);
        }
        let ag = ags
            .get_mut(dest.copy as usize)
            .ok_or_else(|| anyhow!("no AG copy {}", dest.copy))?;
        ag.on_msg(msg, &mut emitted);
        debug_assert!(emitted.is_empty(), "AG emitted a message");
        emitted.clear();
        ag.take_completions(comps);
        for (qid, hits) in comps.drain(..) {
            per_query_secs[qid as usize] =
                dispatch_ts[qid as usize].elapsed().as_secs_f64();
            results[qid as usize] = hits;
            *completed += 1;
            *in_flight = in_flight.saturating_sub(1);
            // The completion ack: closes the inflight loop and drops the
            // remote per-query dedup state. Control — never metered.
            let done = wire::encode_frame(FrameKind::Done, &wire::encode_qid(qid));
            for slot in ctl.live_dp_slots(placement, dp_hosts) {
                peers[slot as usize].send(&done)?;
            }
        }
    }
    Ok(())
}

/// A running multi-process cluster: worker children + the socket executor.
/// Shut it down explicitly with [`NetSession::shutdown`] for a typed exit;
/// dropping the session kills any still-running workers (no leaks either
/// way).
pub struct NetSession {
    /// One entry per worker slot. `None` for slots the session did not
    /// spawn itself: every slot in hosts mode (workers started out of
    /// band at `[net] hosts` addresses) and spawned slots whose process
    /// was killed and not yet respawned. Behind a mutex so the chaos
    /// hooks ([`NetSession::kill_worker`]) work through `&self` while a
    /// streaming run borrows the executor.
    children: Mutex<Vec<Option<Child>>>,
    exec: SocketExecutor,
    /// Shared with `Session.ctl` — the one membership/epoch table.
    cluster: Arc<Mutex<ClusterState>>,
    placement: Placement,
    bin: std::path::PathBuf,
    cfg: Config,
    dim: usize,
    digest: u64,
    /// Discovery mode: `[net] hosts` named the worker addresses; the
    /// session dials instead of spawning, and a healed slot is expected
    /// to have been restarted out of band at its configured address.
    hosts_mode: bool,
    /// `--listen` template for (re)spawned workers (host with port 0 when
    /// several workers would contend for one pinned port).
    spawn_listen: String,
}

/// Canonical shard file path for a worker slot under `net.shard_dir` —
/// what [`NetSession::persist_shards`] writes and what a respawned worker
/// is pointed at (`parlsh worker --shard=...`).
pub fn shard_path(dir: &str, slot: u16) -> String {
    format!("{dir}/slot{slot:03}.shard")
}

/// Spawn one worker process. The caller reads the announce line (possibly
/// after spawning the whole fleet — children bind concurrently).
fn spawn_worker_child(
    bin: &Path,
    listen: &str,
    sock: &SocketConfig,
    shard: Option<&str>,
) -> Result<Child> {
    let mut cmd = Command::new(bin);
    cmd.arg("worker").arg(format!("--listen={listen}"));
    if let Some(path) = shard {
        cmd.arg(format!("--shard={path}"));
    }
    cmd.arg("--set")
        .arg(format!("net.max_frame_bytes={}", sock.max_frame_bytes))
        .arg("--set")
        .arg(format!("net.connect_retries={}", sock.connect_retries))
        .arg("--set")
        .arg(format!("net.retry_ms={}", sock.retry_ms))
        .arg("--set")
        .arg(format!("net.queue_frames={}", sock.queue_frames))
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit());
    cmd.spawn().with_context(|| format!("spawn worker from {}", bin.display()))
}

/// Read a worker's one-line `PARLSH_WORKER_LISTEN <addr>` announce —
/// always the OS-resolved bound address, so port-0 binds work.
fn read_announce(child: &mut Child) -> Result<String> {
    let stdout = child.stdout.take().context("worker stdout already taken")?;
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).context("read worker announce line")?;
    line.trim()
        .strip_prefix("PARLSH_WORKER_LISTEN ")
        .map(str::to_string)
        .ok_or_else(|| anyhow!("worker announced `{}`", line.trim()))
}

/// Kill-then-reap with a short grace period for a process that was asked
/// to exit.
fn reap(mut child: Child) {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        match child.try_wait() {
            Ok(Some(_)) => return,
            Ok(None) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(10));
            }
            _ => {
                child.kill().ok();
                child.wait().ok();
                return;
            }
        }
    }
}

impl NetSession {
    /// Launch workers using this very binary's `worker` subcommand (the
    /// normal path for `parlsh` itself). Override the binary with the
    /// `PARLSH_WORKER_BIN` env var when the current executable is not
    /// `parlsh` (e.g. a test harness).
    pub fn launch(cfg: &Config, dim: usize) -> Result<NetSession> {
        let bin = match std::env::var("PARLSH_WORKER_BIN") {
            Ok(p) => std::path::PathBuf::from(p),
            Err(_) => std::env::current_exe().context("resolve current executable")?,
        };
        Self::launch_with_bin(&bin, cfg, dim)
    }

    /// Bring up one worker process per slot (`logical nodes x
    /// cluster.replication`), connect, and handshake. `dim` is the dataset
    /// dimensionality workers size their DP stores with.
    ///
    /// Two membership modes:
    /// * **spawned** (default) — children are spawned from `bin` on
    ///   loopback, each binding an OS-assigned port and announcing it;
    /// * **discovery** — a non-empty `[net] hosts` lists one address per
    ///   slot; the workers were started out of band (`parlsh worker
    ///   --join=ADDR`) and the session dials them instead of spawning.
    pub fn launch_with_bin(bin: &Path, cfg: &Config, dim: usize) -> Result<NetSession> {
        let placement = Placement::new(&cfg.cluster);
        let n_workers = placement.total_slots();
        let hosts = cfg.sock.host_list();
        let hosts_mode = !hosts.is_empty();
        if hosts_mode && hosts.len() != n_workers {
            bail!(
                "[net] hosts lists {} addresses but this placement has {n_workers} worker \
                 slots ({} logical nodes x replication {})",
                hosts.len(),
                placement.n_logical(),
                placement.replication
            );
        }
        // Several spawned workers cannot share one pinned port: rebind
        // each at port 0 and learn the real address from the announce
        // line (a single spawned worker keeps the configured address).
        let spawn_listen = if !hosts_mode && n_workers > 1 && !cfg.sock.listen.ends_with(":0")
        {
            let (host, _) = cfg.sock.listen.rsplit_once(':').ok_or_else(|| {
                anyhow!("net.listen `{}` has no port; use host:port", cfg.sock.listen)
            })?;
            eprintln!(
                "[parlsh] net.listen `{}` pins one port; {n_workers} spawned workers bind \
                 {host}:0 (OS-assigned) and announce their real addresses",
                cfg.sock.listen
            );
            format!("{host}:0")
        } else {
            cfg.sock.listen.clone()
        };

        // Bring up the fleet. Spawned mode: spawn all first (they bind
        // concurrently), then read each announce line — workers must not
        // write anything else to stdout.
        let mut children: Vec<Option<Child>> = Vec::with_capacity(n_workers);
        let mut addrs: Vec<String> = Vec::with_capacity(n_workers);
        if hosts_mode {
            children.resize_with(n_workers, || None);
            addrs = hosts;
        } else {
            for slot in 0..n_workers {
                let child = spawn_worker_child(bin, &spawn_listen, &cfg.sock, None)
                    .with_context(|| format!("spawn worker slot {slot}"))?;
                children.push(Some(child));
            }
            for (slot, child) in children.iter_mut().enumerate() {
                let child = child.as_mut().expect("just spawned");
                addrs.push(
                    read_announce(child)
                        .with_context(|| format!("worker slot {slot} announce"))?,
                );
            }
        }

        // Connect + handshake each worker; reader threads feed one
        // *bounded* channel (`net.queue_frames`, see the module docs for
        // the closed-loop-vs-open-loop depth argument).
        let digest = wire::config_digest(dim as u32, &cfg.lsh, &cfg.cluster, &cfg.stream);
        let (ev_tx, ev_rx) = mpsc::sync_channel::<DriverEv>(cfg.sock.queue_frames.max(1));
        let mut peers = Vec::with_capacity(n_workers);
        for slot in 0..n_workers {
            let stream = connect_retry(
                &addrs[slot],
                cfg.sock.connect_retries,
                cfg.sock.retry_ms,
            )
            .with_context(|| format!("connect worker slot {slot} at {}", addrs[slot]))?;
            // Writes that stall past the phase-stall horizon fail loudly
            // (typed IO error → phase/stream error) instead of hanging:
            // with the bounded reader queues a fully-wedged
            // backpressure cycle is theoretically reachable under
            // extreme open-loop pressure, and a blocked write has no
            // recv-side stall clock to save it (see the module docs).
            stream.set_write_timeout(Some(PHASE_STALL_TIMEOUT)).ok();
            let reader = stream.try_clone().context("clone worker conn")?;
            spawn_reader(reader, slot as u16, ev_tx.clone(), cfg.sock.max_frame_bytes);
            let mut pc = PeerConn::new(stream, cfg.stream.agg_bytes);
            let hello = Hello {
                node: slot as u16,
                epoch: 0,
                dim: dim as u32,
                peers: addrs.clone(),
                lsh: cfg.lsh,
                cluster: cfg.cluster,
                stream: cfg.stream,
                digest,
            };
            pc.send_now(&wire::encode_frame(FrameKind::Hello, &wire::encode_hello(&hello)))?;
            peers.push(pc);
        }

        // Every worker must pass join validation (config digest + epoch
        // fencing — a fresh session admits only empty workers) before any
        // workload flows.
        let mut ok = vec![false; n_workers];
        let mut acked = 0usize;
        while acked < n_workers {
            match ev_rx.recv_timeout(CONTROL_TIMEOUT) {
                Ok(DriverEv::HelloOk { from, node, digest: d, epoch }) => {
                    if node != from {
                        bail!("worker on conn {from} claims slot {node}");
                    }
                    validate_join(digest, 0, d, epoch)
                        .map_err(|e| anyhow!("worker slot {from} rejected at launch: {e}"))?;
                    if std::mem::replace(&mut ok[from as usize], true) {
                        bail!("worker {from} acked twice");
                    }
                    acked += 1;
                }
                Ok(DriverEv::Stopped { from, reason }) => {
                    bail!("worker {from} stopped during handshake: {reason}")
                }
                Ok(DriverEv::Closed { from, err }) => {
                    bail!("worker {from} closed during handshake: {err}")
                }
                Ok(_) => bail!("unexpected frame during handshake"),
                Err(e) => bail!("handshake: {e}"),
            }
        }

        let cluster = Arc::new(Mutex::new(ClusterState::new(addrs)));
        let ctl = ClusterCtl {
            state: cluster.clone(),
            route: cfg.cluster.replica_route,
            heartbeat: Duration::from_millis(cfg.sock.heartbeat_ms.max(100)),
        };
        // Drop of a half-built fleet: `children` moves into the session
        // below, whose Drop kills anything still running on error paths.
        let session = NetSession {
            children: Mutex::new(children),
            exec: SocketExecutor {
                inner: Mutex::new(Session {
                    peers,
                    ev_tx,
                    ev_rx,
                    placement: placement.clone(),
                    dp_hosts: (cfg.cluster.bi_nodes
                        ..cfg.cluster.bi_nodes + cfg.cluster.dp_nodes)
                        .map(|n| n as u16)
                        .collect(),
                    flush_seq: 0,
                    stream_open: false,
                    broken: false,
                    ctl,
                }),
            },
            cluster,
            placement,
            bin: bin.to_path_buf(),
            cfg: cfg.clone(),
            dim,
            digest,
            hosts_mode,
            spawn_listen,
        };
        Ok(session)
    }

    /// The executor to pass to `build_index_on` / `search_on`.
    pub fn executor(&self) -> &SocketExecutor {
        &self.exec
    }

    /// Current session epoch (completed write phases).
    pub fn epoch(&self) -> u64 {
        self.cluster.lock().unwrap_or_else(|p| p.into_inner()).epoch
    }

    /// Number of slots currently marked dead.
    pub fn n_dead(&self) -> usize {
        self.cluster.lock().unwrap_or_else(|p| p.into_inner()).n_dead()
    }

    /// Is `slot` currently marked live?
    pub fn is_live(&self, slot: u16) -> bool {
        let cs = self.cluster.lock().unwrap_or_else(|p| p.into_inner());
        cs.live.get(slot as usize).copied().unwrap_or(false)
    }

    /// Chaos hook: kill the spawned process behind `slot` outright
    /// (SIGKILL — no goodbye frame). Deliberately does **not** touch the
    /// membership table: detecting the death (broken pipe, heartbeat
    /// silence) is the driver loop's job, which is exactly what chaos
    /// tests exercise — and `&self`, so it can strike while a streaming
    /// run holds the executor. Errors in hosts mode (the session owns no
    /// process) or when the slot's process is already gone.
    pub fn kill_worker(&self, slot: u16) -> Result<()> {
        let mut children = self.children.lock().unwrap_or_else(|p| p.into_inner());
        match children.get_mut(slot as usize).and_then(|c| c.take()) {
            Some(mut child) => {
                child.kill().with_context(|| format!("kill worker slot {slot}"))?;
                child.wait().ok();
                Ok(())
            }
            None => bail!(
                "no spawned process for slot {slot} (hosts mode, or already killed)"
            ),
        }
    }

    /// Shard path to hand a respawned worker, if a persisted shard for
    /// `slot` exists under `net.shard_dir`.
    fn shard_arg(&self, slot: u16) -> Option<String> {
        if self.cfg.sock.shard_dir.is_empty() {
            return None;
        }
        let path = shard_path(&self.cfg.sock.shard_dir, slot);
        Path::new(&path).exists().then_some(path)
    }

    /// Bring a dead slot back mid-session (ISSUE: self-healing rejoin).
    ///
    /// Spawned mode respawns the worker (pointing it at its persisted
    /// shard when one exists); hosts mode assumes the operator restarted
    /// it at the configured address and just redials. The rejoin
    /// handshake carries the *current* epoch, and [`validate_join`]
    /// decides the path:
    ///
    /// * worker answered with the current epoch (shard reload caught it
    ///   up) → fast path, adopt immediately;
    /// * worker answered epoch 0 (empty) → restore path: snapshot a live
    ///   sibling replica of the same logical node and replay it into the
    ///   newcomer via a `Restore` frame;
    /// * anything else (stale shard, wrong config digest) → typed
    ///   [`WireError`] rejection; the session keeps serving on the
    ///   surviving replicas.
    pub fn heal_worker(&self, slot: u16) -> Result<()> {
        let mut s = self.exec.inner.lock().unwrap_or_else(|p| p.into_inner());
        if s.broken {
            bail!("a previous streaming run on this socket executor failed; relaunch the NetSession");
        }
        if s.stream_open {
            bail!("a streaming run is open; finish it before healing a worker");
        }
        if slot as usize >= self.placement.total_slots() {
            bail!("slot {slot} out of range ({} slots)", self.placement.total_slots());
        }
        // The old process may still look "live" (killed between phases,
        // never spoke since): declare it dead first so the membership
        // table is consistent while we bring the replacement up.
        let (cur_epoch, mut addr) = {
            let mut cs = self.cluster.lock().unwrap_or_else(|p| p.into_inner());
            cs.mark_dead(slot);
            (cs.epoch, cs.addrs[slot as usize].clone())
        };
        if let Some(old) = {
            let mut children = self.children.lock().unwrap_or_else(|p| p.into_inner());
            children.get_mut(slot as usize).and_then(|c| c.take())
        } {
            reap(old);
        }
        if !self.hosts_mode {
            let mut child = spawn_worker_child(
                &self.bin,
                &self.spawn_listen,
                &self.cfg.sock,
                self.shard_arg(slot).as_deref(),
            )
            .with_context(|| format!("respawn worker slot {slot}"))?;
            addr = read_announce(&mut child)
                .with_context(|| format!("worker slot {slot} announce"))?;
            self.children.lock().unwrap_or_else(|p| p.into_inner())[slot as usize] =
                Some(child);
        }

        let ctl = s.ctl.clone();
        let Session { peers, ev_rx, ev_tx, .. } = &mut *s;
        // A worker killed *between* runs left its reader's `Closed` (and
        // possibly stray `Pong`s) sitting in the shared event queue with
        // nothing draining it. Sweep dead-slot goodbyes now so the
        // corpse's close is not read as the newcomer failing — the new
        // reader cannot enqueue anything until after the Hello below.
        loop {
            match ev_rx.try_recv() {
                Ok(DriverEv::Pong { .. }) => {}
                Ok(DriverEv::Stopped { from, .. }) | Ok(DriverEv::Closed { from, .. })
                    if !ctl.is_live(from) => {}
                Ok(_) => bail!("unexpected event queued before rejoin (a live worker died?)"),
                Err(_) => break,
            }
        }
        let stream = connect_retry(
            &addr,
            self.cfg.sock.connect_retries,
            self.cfg.sock.retry_ms,
        )
        .with_context(|| format!("reconnect worker slot {slot} at {addr}"))?;
        stream.set_write_timeout(Some(PHASE_STALL_TIMEOUT)).ok();
        let reader = stream.try_clone().context("clone worker conn")?;
        spawn_reader(reader, slot, ev_tx.clone(), self.cfg.sock.max_frame_bytes);
        let mut pc = PeerConn::new(stream, self.cfg.stream.agg_bytes);

        let mut hello_peers = {
            let cs = self.cluster.lock().unwrap_or_else(|p| p.into_inner());
            cs.addrs.clone()
        };
        hello_peers[slot as usize] = addr.clone();
        let hello = Hello {
            node: slot,
            epoch: cur_epoch,
            dim: self.dim as u32,
            peers: hello_peers,
            lsh: self.cfg.lsh,
            cluster: self.cfg.cluster,
            stream: self.cfg.stream,
            digest: self.digest,
        };
        pc.send_now(&wire::encode_frame(FrameKind::Hello, &wire::encode_hello(&hello)))?;

        // Await the newcomer's HelloOk. Frames from *dead* slots (their
        // reader threads announcing the close we caused) are expected
        // noise; anything from a live slot is a protocol error.
        let (d, e) = loop {
            match ev_rx.recv_timeout(CONTROL_TIMEOUT) {
                Ok(DriverEv::HelloOk { from, node, digest, epoch }) if from == slot => {
                    if node != slot {
                        bail!("worker on conn {from} claims slot {node}");
                    }
                    break (digest, epoch);
                }
                Ok(DriverEv::Pong { .. }) => {}
                Ok(DriverEv::Stopped { from, reason }) => {
                    if ctl.is_live(from) {
                        bail!("worker {from} stopped during rejoin: {reason}");
                    }
                }
                Ok(DriverEv::Closed { from, err }) => {
                    if ctl.is_live(from) || from == slot {
                        bail!("worker {from} closed during rejoin: {err}");
                    }
                }
                Ok(_) => bail!("unexpected frame during rejoin handshake"),
                Err(e) => bail!("rejoin handshake: {e}"),
            }
        };
        let path = match validate_join(self.digest, cur_epoch, d, e) {
            Ok(p) => p,
            Err(werr) => {
                // Typed rejection (stale shard / wrong config): tell the
                // worker to exit, reap it, keep serving on survivors.
                pc.send_now(&wire::encode_frame(FrameKind::Shutdown, &[])).ok();
                if let Some(child) = {
                    let mut children =
                        self.children.lock().unwrap_or_else(|p| p.into_inner());
                    children.get_mut(slot as usize).and_then(|c| c.take())
                } {
                    reap(child);
                }
                return Err(anyhow::Error::new(werr)
                    .context(format!("worker slot {slot} rejoin rejected")));
            }
        };

        if matches!(path, RejoinPath::NeedsRestore) {
            // Replay a live sibling replica's state into the newcomer.
            let node = self.placement.node_of_slot(slot);
            let sibling = {
                let cs = self.cluster.lock().unwrap_or_else(|p| p.into_inner());
                cs.live_slots_of(&self.placement, node).first().copied()
            };
            let Some(sib) = sibling else {
                bail!(
                    "logical node {node} has no live replica to restore slot {slot} from"
                );
            };
            peers[sib as usize].send_now(&wire::encode_frame(FrameKind::StateReq, &[]))?;
            let state = loop {
                match ev_rx.recv_timeout(CONTROL_TIMEOUT) {
                    Ok(DriverEv::State { from, state }) if from == sib => break state,
                    Ok(DriverEv::Pong { .. }) => {}
                    Ok(DriverEv::Stopped { from, reason }) => {
                        if ctl.is_live(from) {
                            bail!("worker {from} stopped during restore: {reason}");
                        }
                    }
                    Ok(DriverEv::Closed { from, err }) => {
                        if ctl.is_live(from) || from == slot {
                            bail!("worker {from} closed during restore: {err}");
                        }
                    }
                    Ok(_) => bail!("unexpected frame during restore"),
                    Err(e) => bail!("restore snapshot: {e}"),
                }
            };
            let dump = wire::encode_node_state(&state);
            pc.send_now(&wire::encode_frame(
                FrameKind::Restore,
                &wire::encode_restore(cur_epoch, &dump),
            ))?;
            loop {
                match ev_rx.recv_timeout(CONTROL_TIMEOUT) {
                    Ok(DriverEv::RestoreOk { from, slot: sl }) if from == slot && sl == slot => {
                        break
                    }
                    Ok(DriverEv::Pong { .. }) => {}
                    Ok(DriverEv::Stopped { from, reason }) => {
                        if ctl.is_live(from) {
                            bail!("worker {from} stopped during restore: {reason}");
                        }
                    }
                    Ok(DriverEv::Closed { from, err }) => {
                        if ctl.is_live(from) || from == slot {
                            bail!("worker {from} closed during restore: {err}");
                        }
                    }
                    Ok(_) => bail!("unexpected frame during restore"),
                    Err(e) => bail!("restore ack: {e}"),
                }
            }
        }

        // Adopt: swap the connection in, flip the slot live, tell the
        // whole fleet about the new table.
        peers[slot as usize] = pc;
        let (frame, live) = {
            let mut cs = self.cluster.lock().unwrap_or_else(|p| p.into_inner());
            cs.mark_live(slot, addr);
            (membership_frame(&cs), cs.live.clone())
        };
        for (sl, p) in peers.iter_mut().enumerate() {
            if live[sl] {
                p.send_now(&frame)
                    .with_context(|| format!("announce rejoin to slot {sl}"))?;
            }
        }
        Ok(())
    }

    /// Ask every live worker to persist its shard to `net.shard_dir`
    /// (PLSD files via `coordinator/persist`, fenced with the current
    /// epoch + config digest). Returns the written paths, slot-ordered.
    pub fn persist_shards(&self) -> Result<Vec<String>> {
        if self.cfg.sock.shard_dir.is_empty() {
            bail!("net.shard_dir is empty; set it to persist worker shards");
        }
        let mut s = self.exec.inner.lock().unwrap_or_else(|p| p.into_inner());
        if s.broken {
            bail!("a previous streaming run on this socket executor failed; relaunch the NetSession");
        }
        if s.stream_open {
            bail!("a streaming run is open; finish it before persisting shards");
        }
        std::fs::create_dir_all(&self.cfg.sock.shard_dir)
            .with_context(|| format!("create shard dir {}", self.cfg.sock.shard_dir))?;
        let (epoch, live) = {
            let cs = self.cluster.lock().unwrap_or_else(|p| p.into_inner());
            (cs.epoch, cs.live.clone())
        };
        let ctl = s.ctl.clone();
        let Session { peers, ev_rx, .. } = &mut *s;
        let mut paths = Vec::new();
        let mut expect = 0usize;
        for (sl, p) in peers.iter_mut().enumerate() {
            if !live[sl] {
                continue;
            }
            let path = shard_path(&self.cfg.sock.shard_dir, sl as u16);
            p.send_now(&wire::encode_frame(
                FrameKind::PersistReq,
                &wire::encode_persist_req(epoch, &path),
            ))?;
            paths.push(path);
            expect += 1;
        }
        let mut acked = 0usize;
        while acked < expect {
            match ev_rx.recv_timeout(CONTROL_TIMEOUT) {
                Ok(DriverEv::PersistOk { .. }) => acked += 1,
                Ok(DriverEv::Pong { .. }) => {}
                Ok(DriverEv::Stopped { from, reason }) => {
                    if ctl.is_live(from) {
                        bail!("worker {from} stopped during persist: {reason}");
                    }
                }
                Ok(DriverEv::Closed { from, err }) => {
                    if ctl.is_live(from) {
                        bail!("worker {from} closed during persist: {err}");
                    }
                }
                Ok(_) => bail!("unexpected frame during persist"),
                Err(e) => bail!("shard persist: {e}"),
            }
        }
        Ok(paths)
    }

    /// Snapshot every *live* worker's BI buckets and DP objects
    /// (differential tests; one `(slot, state)` pair per live slot,
    /// slot-sorted — dead slots are simply absent).
    pub fn fetch_state(&self) -> Result<Vec<(u16, NodeState)>> {
        let mut s = self.exec.inner.lock().unwrap_or_else(|p| p.into_inner());
        if s.broken {
            bail!("a previous streaming run on this socket executor failed; relaunch the NetSession");
        }
        if s.stream_open {
            bail!("a streaming run is open; finish it before snapshotting worker state");
        }
        if s.peers.len() + 1 != s.placement.total_nodes() {
            bail!(
                "socket executor holds {}/{} worker connections (a streaming run died \
                 without returning them)",
                s.peers.len(),
                s.placement.total_nodes() - 1
            );
        }
        let live = s.ctl.live_mask();
        let ctl = s.ctl.clone();
        let Session { peers, ev_rx, .. } = &mut *s;
        let req = wire::encode_frame(FrameKind::StateReq, &[]);
        let mut expect = 0usize;
        for (sl, p) in peers.iter_mut().enumerate() {
            if live[sl] {
                p.send_now(&req)?;
                expect += 1;
            }
        }
        let mut out = Vec::with_capacity(expect);
        while out.len() < expect {
            match ev_rx.recv_timeout(CONTROL_TIMEOUT) {
                Ok(DriverEv::State { from, state }) => out.push((from, state)),
                Ok(DriverEv::Pong { .. }) => {}
                Ok(DriverEv::Stopped { from, reason }) => {
                    if ctl.is_live(from) {
                        bail!("worker {from} stopped during snapshot: {reason}");
                    }
                }
                Ok(DriverEv::Closed { from, err }) => {
                    if ctl.is_live(from) {
                        bail!("worker {from} closed during snapshot: {err}");
                    }
                }
                Ok(_) => bail!("unexpected frame during snapshot"),
                Err(e) => bail!("state snapshot: {e}"),
            }
        }
        out.sort_by_key(|(node, _)| *node);
        Ok(out)
    }

    /// Typed shutdown: ask every live worker to exit, then join every
    /// spawned child, failing on any nonzero exit from a live worker.
    /// Dead slots' processes (if any linger) are killed, not judged.
    /// Workers that ignore the request are killed (and reported) rather
    /// than leaked.
    pub fn shutdown(mut self) -> Result<()> {
        let live = {
            let mut s = self.exec.inner.lock().unwrap_or_else(|p| p.into_inner());
            if s.stream_open {
                bail!("a streaming run is open; finish it before shutting the workers down");
            }
            if s.peers.len() + 1 != s.placement.total_nodes() {
                bail!(
                    "socket executor holds {}/{} worker connections (a streaming run died \
                     without returning them); workers will be killed, not joined",
                    s.peers.len(),
                    s.placement.total_nodes() - 1
                );
            }
            let live = s.ctl.live_mask();
            let frame = wire::encode_frame(FrameKind::Shutdown, &[]);
            for (sl, p) in s.peers.iter_mut().enumerate() {
                if live[sl] {
                    p.send_now(&frame)?;
                }
            }
            live
        };
        let children = std::mem::take(
            &mut *self.children.lock().unwrap_or_else(|p| p.into_inner()),
        );
        for (slot, child_opt) in children.into_iter().enumerate() {
            let Some(mut child) = child_opt else { continue };
            if !live[slot] {
                child.kill().ok();
                child.wait().ok();
                continue;
            }
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                match child.try_wait().with_context(|| format!("wait worker slot {slot}"))? {
                    Some(status) if status.success() => break,
                    Some(status) => bail!("worker slot {slot} exited with {status}"),
                    None if Instant::now() >= deadline => {
                        child.kill().ok();
                        child.wait().ok();
                        bail!("worker slot {slot} ignored shutdown; killed");
                    }
                    None => std::thread::sleep(Duration::from_millis(10)),
                }
            }
        }
        Ok(())
    }
}

impl Drop for NetSession {
    fn drop(&mut self) {
        // Error paths only: `shutdown` drains `children` first.
        let children = self.children.get_mut().unwrap_or_else(|p| p.into_inner());
        for child in children.iter_mut().flatten() {
            child.kill().ok();
            child.wait().ok();
        }
    }
}

fn spawn_reader(stream: TcpStream, from: u16, tx: SyncSender<DriverEv>, max_frame: usize) {
    std::thread::spawn(move || reader_loop(stream, from, tx, max_frame));
}

/// One reader per worker connection. The `tx` channel is bounded: a full
/// driver queue blocks this thread, which stops draining the socket and
/// backpressures the worker's TCP sender (see the module docs).
fn reader_loop(mut stream: TcpStream, from: u16, tx: SyncSender<DriverEv>, max_frame: usize) {
    loop {
        let frame = match wire::read_frame(&mut stream, max_frame) {
            Ok(f) => f,
            Err(e) => {
                let _ = tx.send(DriverEv::Closed { from, err: e.to_string() });
                return;
            }
        };
        let ev = match frame.kind {
            FrameKind::HelloOk => wire::decode_hello_ok(&frame.payload)
                .map(|(node, digest, epoch)| DriverEv::HelloOk { from, node, digest, epoch }),
            FrameKind::Pong => wire::decode_epoch(&frame.payload)
                .map(|epoch| DriverEv::Pong { from, epoch }),
            FrameKind::RestoreOk => wire::decode_slot_ack(&frame.payload)
                .map(|slot| DriverEv::RestoreOk { from, slot }),
            FrameKind::PersistOk => wire::decode_slot_ack(&frame.payload)
                .map(|slot| DriverEv::PersistOk { from, slot }),
            FrameKind::Stage => wire::decode_stage(&frame.payload)
                .map(|(dest, msg)| DriverEv::Msg { from, dest, msg }),
            FrameKind::FlushAck => wire::decode_flush_ack(&frame.payload)
                .map(|(seq, meter, work)| DriverEv::FlushAck { from, seq, meter, work }),
            FrameKind::StateDump => wire::decode_state_dump(&frame.payload)
                .map(|state| DriverEv::State { from, state }),
            FrameKind::Stopped => wire::decode_stopped(&frame.payload)
                .map(|reason| DriverEv::Stopped { from, reason }),
            other => Err(anyhow!("unexpected frame {other:?} from worker {from}")),
        };
        match ev {
            Ok(ev) => {
                let stop = matches!(ev, DriverEv::Stopped { .. });
                if tx.send(ev).is_err() || stop {
                    return;
                }
            }
            Err(e) => {
                let _ = tx.send(DriverEv::Closed { from, err: e.to_string() });
                return;
            }
        }
    }
}
