//! The socket-transport launcher and executor: spawns `parlsh worker`
//! processes on loopback, handshakes them, and drives the five-stage
//! pipeline across real OS processes through the transport-agnostic
//! [`Executor`] seam.
//!
//! Topology follows the paper via the shared [`Placement`]: the *driver*
//! process is the head node (IR/QR ingress + every AG copy, where global
//! top-k reduction and completion accounting live), and each BI/DP node is
//! one worker process. A [`NetSession`] outlives individual phases —
//! worker-side BI/DP state persists between `build_index_on` and
//! `search_on`, exactly like the in-process `Cluster` does — and ends with
//! a typed `Shutdown` that joins every worker (no leaked processes).
//!
//! Besides one-shot phase runs, [`SocketExecutor`] implements
//! [`Executor::open_stream`]: a dedicated admission thread takes over the
//! hot worker connections for the run's lifetime, submissions are admitted
//! the moment they arrive (no per-pump workload), and the
//! `FlushReq`/`FlushAck` meter barrier runs once per stream at `finish`
//! instead of once per pump.
//!
//! **Bounded wire fan-in.** The driver-side event queue — one channel
//! unifying every worker reader's decoded frames with a streaming run's
//! ingress — is *bounded* by `net.queue_frames` (the same knob that
//! bounds worker reader→dispatch queues): a full queue blocks the reader
//! threads, which stop draining their sockets, which backpressures the
//! workers' TCP senders instead of buffering an unbounded event backlog
//! in driver memory. Depth argument: in **closed-loop** operation
//! (`stream.inflight = W`) at most `W · (n_bi + n_dp + 1)` wire events
//! can be outstanding per the completion accounting (QueryMeta + BiMetas
//! + LocalTopKs per in-flight query), so a default-sized queue (1024
//! frames) never fills and the bound is free. In **open-loop** operation
//! the bound is what limits the *wire* backlog: fan-in beyond the queue
//! parks in kernel TCP buffers and ultimately in the workers' own
//! bounded queues, so pressure propagates along the dataflow DAG
//! (worker → driver is its last edge — the driver's admission loops
//! always drain this queue before blocking, which is what keeps the
//! cycle through `peers[..].send` unreachable at the default depth; size
//! `net.queue_frames` ≥ the expected per-query fan-in times the
//! concurrent query count). Streaming ingress shares the channel: the
//! blocking `submit` path parks in short ticks while it is full and
//! fails loudly (never wedges) if the admission thread is gone; the
//! non-blocking `try_submit` path treats a full channel as a decline,
//! exactly like a full backpressure window, so callers holding their own
//! locks are never parked here. Note what the bound does **not** cover:
//! ingress the admission loop has already accepted but deferred behind
//! the closed-loop window sits in its in-memory `pending` queue, whose
//! depth is governed end-to-end by `stream.pending_cap` (the session
//! gate; 0 = the caller chose unbounded) — same contract as the
//! in-process streaming runs.
//!
//! Residual hazard, and why it fails loudly instead of hanging: with
//! blocking IO, bounding the worker→driver edge weakens PR 4's DAG
//! argument ("the driver always drains its side") — under extreme
//! open-loop pressure a full cycle can wedge (driver blocked in a peer
//! `send` ⇢ worker not reading ⇢ worker blocked writing results ⇢
//! driver readers parked on the full queue ⇢ nobody drains). The
//! admission loop's recv-side stall clock cannot fire while the driver
//! is blocked in a *write*, so every driver↔worker socket carries a
//! write timeout at the same `PHASE_STALL_TIMEOUT` horizon: a wedged
//! cycle surfaces as a typed IO error that fails the phase/stream (and
//! tears the fleet down) rather than a silent permanent hang.
//! Closed-loop windows or `stream.pending_cap` keep the cycle
//! unreachable in the first place; removing it entirely is the
//! poll-based-IO ROADMAP item.
//!
//! [`SocketExecutor::run`] mirrors the threaded executor's admission loop:
//! closed-loop batched admission via `Workload::window`, completion events
//! from the (local) AG copies, and per-query `Done` acks fanned out to the
//! DP-hosting workers — the ack closes the `stream.inflight` loop and
//! tears down remote dedup state. A worker that dies mid-phase surfaces as
//! a typed `Stopped`/closed event and fails the phase loudly instead of
//! hanging the admission loop. Traffic accounting is real: every encoded
//! frame is charged with its actual on-wire length (header included) on
//! the sender's meter, and worker meters come back in `FlushAck` barriers
//! at phase end, so `ExecReport::meter` holds measured per-link TCP bytes,
//! not the `wire_size` model.

use crate::config::Config;
use crate::dataflow::exec::{
    ExecReport, Executor, GateGuard, StageHandler, StageHandlers, StreamCompletion,
    StreamConfig, StreamGate, StreamReport, StreamRun, Workload,
};
use crate::dataflow::message::{Dest, Msg, StageKind};
use crate::dataflow::metrics::{TrafficMeter, WorkStats};
use crate::dataflow::Placement;
use crate::net::peer::{connect_retry, PeerConn};
use crate::net::wire::{self, FrameKind, Hello, NodeState};
use crate::stages::aggregator::QueryResult;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader};
use std::net::TcpStream;
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How long a streaming submitter parks between attempts while the bounded
/// driver event queue is full. Only paid when wire fan-in saturates the
/// queue — the backpressure path, where event latency dominates anyway.
const EV_FULL_TICK: Duration = Duration::from_micros(200);

/// How long to wait on control responses (handshake, barriers, snapshots).
const CONTROL_TIMEOUT: Duration = Duration::from_secs(30);
/// How long a phase may sit with no event at all before we call it wedged.
const PHASE_STALL_TIMEOUT: Duration = Duration::from_secs(120);

/// Events the per-worker reader threads feed the driver. `Ingress` and
/// `Finish` come from a streaming run's handle instead of a socket — one
/// unified channel stands in for a select over submissions + wire events.
enum DriverEv {
    HelloOk { from: u16, node: u16, digest: u64 },
    Msg { from: u16, dest: Dest, msg: Msg },
    FlushAck {
        from: u16,
        seq: u32,
        meter: TrafficMeter,
        work: Vec<(StageKind, u16, WorkStats)>,
    },
    State { from: u16, state: NodeState },
    Stopped { from: u16, reason: String },
    Closed { from: u16, err: String },
    /// Streaming submission ([`StreamRun::submit`]).
    Ingress(Msg),
    /// Streaming barrier: wind the run down at quiescence.
    Finish,
}

struct Session {
    peers: Vec<PeerConn>,
    ev_rx: Receiver<DriverEv>,
    /// Sender half of `ev_rx` (bounded, `net.queue_frames`) — streaming
    /// runs clone it for their ingress.
    ev_tx: SyncSender<DriverEv>,
    placement: Placement,
    /// Worker nodes hosting at least one DP copy (get per-query `Done`s).
    dp_hosts: Vec<u16>,
    flush_seq: u32,
    /// A streaming run currently owns `peers`/`ev_rx`; phase runs,
    /// snapshots and shutdown must wait for its `finish`.
    stream_open: bool,
    /// A streaming run died on this executor. The returned connection
    /// state may hold stale events (undrained ingress, in-flight frames
    /// for cancelled queries), so everything except `shutdown` refuses to
    /// touch it — relaunch the `NetSession` instead of risking a poisoned
    /// phase on a half-dead fleet.
    broken: bool,
}

/// An [`Executor`] that runs BI/DP stages on remote worker processes. The
/// local `bis`/`dps` handlers in [`StageHandlers`] are intentionally not
/// driven — that state lives (and persists across phases) in the workers;
/// fetch it with [`NetSession::fetch_state`].
pub struct SocketExecutor {
    inner: Mutex<Session>,
}

impl Executor for SocketExecutor {
    fn run(
        &self,
        placement: &Placement,
        stages: StageHandlers<'_>,
        workload: Workload<'_>,
    ) -> ExecReport {
        let mut s = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        match s.run_phase(placement, stages, workload) {
            Ok(report) => report,
            // Mirror the threaded executor: a dead stage (here: worker)
            // resurfaces loudly instead of wedging the admission loop.
            Err(e) => panic!("socket phase failed: {e}"),
        }
    }

    /// A streaming run over the live worker fleet: connections stay hot,
    /// submissions are admitted the moment they arrive, and the
    /// `FlushReq`/`FlushAck` barrier happens once per stream (at `finish`)
    /// instead of once per pump. The admission loop moves onto a dedicated
    /// thread that owns the peer connections for the run's lifetime.
    fn open_stream<'e>(
        &'e self,
        placement: &Placement,
        stages: StageHandlers<'static>,
        cfg: StreamConfig,
    ) -> Box<dyn StreamRun + 'e> {
        let mut s = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if s.broken {
            panic!("a previous streaming run on this socket executor failed; relaunch the NetSession");
        }
        if s.stream_open {
            panic!("a streaming run is already open on this socket executor");
        }
        if s.peers.len() + 1 != s.placement.total_nodes() {
            panic!(
                "socket executor holds {}/{} worker connections (a streaming run died \
                 without returning them); relaunch the NetSession",
                s.peers.len(),
                s.placement.total_nodes() - 1
            );
        }
        if *placement != s.placement {
            panic!("stream placement differs from the placement workers were launched with");
        }
        let peers = std::mem::take(&mut s.peers);
        let ev_rx = std::mem::replace(&mut s.ev_rx, mpsc::sync_channel(1).1);
        let ev_tx = s.ev_tx.clone();
        let dp_hosts = s.dp_hosts.clone();
        let flush_seq = s.flush_seq;
        s.stream_open = true;
        drop(s);

        let StageHandlers { head, bis, dps, ags } = stages;
        drop(bis); // BI/DP state lives in the workers, not behind these
        drop(dps);

        let gate = Arc::new(StreamGate::new(cfg.pending_cap));
        let (eg_tx, eg_rx) = mpsc::channel::<StreamCompletion>();
        let g = gate.clone();
        let p = placement.clone();
        let admission = std::thread::spawn(move || {
            socket_stream_loop(
                head, ags, peers, ev_rx, eg_tx, g, p, dp_hosts, cfg, flush_seq,
            )
        });
        Box::new(SocketStreamRun {
            exec: self,
            ev_tx,
            gate,
            egress_rx: eg_rx,
            admission: Some(admission),
        })
    }
}

/// What the socket streaming admission thread hands back at join: the
/// run's accounting plus the connection state it borrowed from the
/// executor, restored by [`SocketStreamRun::finish`].
struct SocketStreamJoin {
    peers: Vec<PeerConn>,
    ev_rx: Receiver<DriverEv>,
    meter: TrafficMeter,
    work: Vec<(StageKind, u16, WorkStats)>,
    flush_seq: u32,
    error: Option<String>,
}

/// The socket transport's [`StreamRun`] handle.
pub struct SocketStreamRun<'e> {
    exec: &'e SocketExecutor,
    ev_tx: SyncSender<DriverEv>,
    gate: Arc<StreamGate>,
    egress_rx: Receiver<StreamCompletion>,
    admission: Option<std::thread::JoinHandle<SocketStreamJoin>>,
}

/// Enqueue `Finish` on the bounded event queue without ever wedging: if
/// the admission thread already exited (error path — nobody drains the
/// queue anymore), skip the send and let the caller join directly.
fn send_finish(
    ev_tx: &SyncSender<DriverEv>,
    admission: &Option<std::thread::JoinHandle<SocketStreamJoin>>,
) {
    loop {
        match admission {
            None => return,
            Some(h) if h.is_finished() => return,
            Some(_) => {}
        }
        match ev_tx.try_send(DriverEv::Finish) {
            Ok(()) => return,
            Err(TrySendError::Full(_)) => std::thread::sleep(EV_FULL_TICK),
            Err(TrySendError::Disconnected(_)) => return,
        }
    }
}

impl SocketStreamRun<'_> {
    /// True when the admission thread can no longer drain the queue (gone
    /// or already exited) — continuing to wait on it would wedge.
    fn admission_gone(&self) -> bool {
        self.admission.as_ref().map(|h| h.is_finished()).unwrap_or(true)
    }

    /// Enqueue one ingress event on the bounded driver queue. Parks in
    /// short ticks while wire fan-in holds the queue full (backpressure —
    /// the queue is shared with the worker readers) and dies loudly if
    /// the admission thread is gone instead of blocking forever. Only the
    /// *blocking* [`StreamRun::submit`] path uses this; `try_submit` stays
    /// genuinely non-blocking (a full queue is a decline there).
    fn send_ingress(&mut self, msg: Msg) {
        let mut ev = DriverEv::Ingress(msg);
        loop {
            match self.ev_tx.try_send(ev) {
                Ok(()) => return,
                Err(TrySendError::Full(back)) => {
                    if self.admission_gone() {
                        self.die();
                    }
                    ev = back;
                    std::thread::sleep(EV_FULL_TICK);
                }
                Err(TrySendError::Disconnected(_)) => self.die(),
            }
        }
    }

    /// Wind the admission thread down and hand the connections back to the
    /// executor, returning the run's accounting (+ typed failure, if any).
    fn wind_down(&mut self) -> (TrafficMeter, Vec<(StageKind, u16, WorkStats)>, Option<String>) {
        send_finish(&self.ev_tx, &self.admission);
        let handle = self.admission.take().expect("socket stream already wound down");
        let join = handle
            .join()
            .unwrap_or_else(|p| std::panic::resume_unwind(p));
        let mut s = self.exec.inner.lock().unwrap_or_else(|p| p.into_inner());
        s.peers = join.peers;
        s.ev_rx = join.ev_rx;
        s.flush_seq = join.flush_seq;
        s.stream_open = false;
        // A died stream can leave stale events (undrained ingress,
        // frames for cancelled queries) in the restored channel: refuse
        // further use instead of poisoning the next phase.
        s.broken |= join.error.is_some();
        (join.meter, join.work, join.error)
    }

    fn die(&mut self) -> ! {
        if self.admission.is_some() {
            let (_, _, error) = self.wind_down();
            if let Some(e) = error {
                panic!("socket stream failed: {e}");
            }
        }
        panic!("socket stream run died");
    }
}

impl StreamRun for SocketStreamRun<'_> {
    fn submit(&mut self, msg: Msg) {
        let gated = msg.qid().is_some();
        if gated && !self.gate.acquire() {
            self.die();
        }
        self.send_ingress(msg);
    }

    fn try_submit(&mut self, msg: Msg) -> std::result::Result<(), Msg> {
        let gated = msg.qid().is_some();
        if gated {
            match self.gate.try_acquire() {
                Ok(true) => {}
                Ok(false) => return Err(msg),
                Err(()) => self.die(),
            }
        }
        // Genuinely non-blocking: a full driver queue is a decline, same
        // as a full backpressure window — callers (the session's
        // try_submit_one runs under the session mutex) must never be
        // parked here, or non-blocking calls would stall behind us.
        match self.ev_tx.try_send(DriverEv::Ingress(msg)) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(ev)) => {
                if gated {
                    self.gate.release();
                }
                if self.admission_gone() {
                    self.die();
                }
                match ev {
                    DriverEv::Ingress(m) => Err(m),
                    _ => unreachable!("try_send returned a different event"),
                }
            }
            Err(TrySendError::Disconnected(_)) => self.die(),
        }
    }

    fn can_submit(&self) -> bool {
        self.gate.has_room()
    }

    fn recv(&mut self, timeout: Duration) -> Option<StreamCompletion> {
        match self.egress_rx.recv_timeout(timeout) {
            Ok(c) => Some(c),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => self.die(),
        }
    }

    fn try_recv(&mut self) -> Option<StreamCompletion> {
        match self.egress_rx.try_recv() {
            Ok(c) => Some(c),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => self.die(),
        }
    }

    fn finish(mut self: Box<Self>) -> StreamReport {
        let (meter, work, error) = self.wind_down();
        if let Some(e) = error {
            panic!("socket stream failed: {e}");
        }
        let mut unclaimed = Vec::new();
        while let Ok(c) = self.egress_rx.try_recv() {
            unclaimed.push(c);
        }
        StreamReport { unclaimed, meter, work }
    }
}

impl Drop for SocketStreamRun<'_> {
    fn drop(&mut self) {
        // Dropped without `finish` (caller unwound): wind down and restore
        // the connections without panicking — aborting during an unwind
        // would take the whole process down.
        send_finish(&self.ev_tx, &self.admission);
        if let Some(handle) = self.admission.take() {
            match handle.join() {
                Ok(join) => {
                    let mut s = self.exec.inner.lock().unwrap_or_else(|p| p.into_inner());
                    s.peers = join.peers;
                    s.ev_rx = join.ev_rx;
                    s.flush_seq = join.flush_seq;
                    s.stream_open = false;
                    s.broken |= join.error.is_some();
                }
                Err(_) => {
                    // The admission thread panicked and took the worker
                    // connections down with it. Clear the stream flag so
                    // the executor fails on the lost-connection guard
                    // (the real story) instead of wedging forever behind
                    // a misleading "stream open" error.
                    eprintln!(
                        "[parlsh] socket stream admission thread panicked; \
                         worker connections lost"
                    );
                    let mut s = self.exec.inner.lock().unwrap_or_else(|p| p.into_inner());
                    s.stream_open = false;
                    s.broken = true;
                }
            }
        }
    }
}

/// The socket streaming admission loop (its own thread): the streaming
/// rendition of [`Session::run_phase`] — closed-loop windowed admission,
/// deferred ingress, local AG delivery, per-completion `Done` acks and
/// gate releases — with the worker-meter barrier run once at the end.
#[allow(clippy::too_many_arguments)]
fn socket_stream_loop(
    mut head: Box<dyn StageHandler>,
    mut ags: Vec<Box<dyn StageHandler>>,
    mut peers: Vec<PeerConn>,
    ev_rx: Receiver<DriverEv>,
    egress: mpsc::Sender<StreamCompletion>,
    gate: Arc<StreamGate>,
    placement: Placement,
    dp_hosts: Vec<u16>,
    cfg: StreamConfig,
    mut flush_seq: u32,
) -> SocketStreamJoin {
    // Opens the gate on every exit path so blocked submitters never hang
    // on a dead run.
    let _gg = GateGuard(gate.clone());
    let mut meter = TrafficMeter::new(cfg.agg_bytes);
    meter.header_bytes = 0; // frames carry their real header in len
    let head_node = placement.head_node;
    let mut emitted: Vec<(Dest, Msg)> = Vec::new();
    let mut pending: VecDeque<Msg> = VecDeque::new();
    let mut local_q: VecDeque<(Dest, Msg)> = VecDeque::new();
    let mut comps: Vec<QueryResult> = Vec::new();
    let mut dispatch_ts: HashMap<u32, Instant> = HashMap::new();
    let mut in_flight = 0usize;
    let mut finishing = false;
    let mut error: Option<String> = None;

    'run: loop {
        // Admit deferred ingress while the window allows (non-query items
        // are never windowed — same policy as the phase run).
        while error.is_none() {
            let next_is_query = match pending.front() {
                None => break,
                Some(m) => m.qid().is_some(),
            };
            if next_is_query && cfg.window != 0 && in_flight >= cfg.window {
                break;
            }
            let item = pending.pop_front().expect("peeked non-empty");
            let item_qid = item.qid();
            head.on_msg(item, &mut emitted);
            if let Some(qid) = item_qid {
                dispatch_ts.insert(qid, Instant::now());
                in_flight += 1;
            }
            for (dest, msg) in emitted.drain(..) {
                let node = placement.node_of(dest.stage, dest.copy);
                if node == head_node {
                    meter.send(head_node, head_node, 0);
                    local_q.push_back((dest, msg));
                } else {
                    let frame = wire::stage_frame(dest, &msg);
                    meter.send(head_node, node, frame.len());
                    if let Err(e) = peers[node as usize].send(&frame) {
                        error = Some(format!("send to worker {node}: {e}"));
                        break;
                    }
                }
            }
            if error.is_some() {
                break;
            }
            if let Err(e) = drain_local_stream(
                &mut local_q,
                &mut ags,
                &mut comps,
                &mut dispatch_ts,
                &mut in_flight,
                &mut peers,
                &dp_hosts,
                &gate,
                &egress,
            ) {
                error = Some(e);
            }
        }
        if error.is_some() || (finishing && pending.is_empty() && in_flight == 0) {
            break 'run;
        }
        // Everything queued must reach the wire before blocking, or the
        // closed loop deadlocks on a buffered frame.
        for p in peers.iter_mut() {
            if let Err(e) = p.flush() {
                error = Some(format!("flush: {e}"));
                continue 'run;
            }
        }
        // Idle is normal on a long-lived stream, so the stall clock only
        // runs while queries are actually in flight.
        let ev = if in_flight > 0 {
            match ev_rx.recv_timeout(PHASE_STALL_TIMEOUT) {
                Ok(ev) => ev,
                Err(RecvTimeoutError::Timeout) => {
                    error = Some(format!(
                        "stream stalled: {in_flight} queries in flight after {}s of silence",
                        PHASE_STALL_TIMEOUT.as_secs()
                    ));
                    continue 'run;
                }
                Err(RecvTimeoutError::Disconnected) => {
                    error = Some("all worker readers exited".into());
                    continue 'run;
                }
            }
        } else {
            match ev_rx.recv() {
                Ok(ev) => ev,
                Err(_) => {
                    error = Some("all worker readers exited".into());
                    continue 'run;
                }
            }
        };
        match ev {
            DriverEv::Ingress(m) => pending.push_back(m),
            DriverEv::Finish => finishing = true,
            DriverEv::Msg { dest, msg, .. } => {
                local_q.push_back((dest, msg));
                if let Err(e) = drain_local_stream(
                    &mut local_q,
                    &mut ags,
                    &mut comps,
                    &mut dispatch_ts,
                    &mut in_flight,
                    &mut peers,
                    &dp_hosts,
                    &gate,
                    &egress,
                ) {
                    error = Some(e);
                }
            }
            DriverEv::Stopped { from, reason } => {
                error = Some(format!("worker {from} stopped mid-stream: {reason}"));
            }
            DriverEv::Closed { from, err } => {
                error = Some(format!("worker {from} connection lost mid-stream: {err}"));
            }
            _ => error = Some("unexpected control frame mid-stream".into()),
        }
    }

    // Quiescence barrier: collect every worker's meter and per-copy work
    // exactly once per stream — not once per pump. Skipped if the run
    // already died.
    let mut work: Vec<(StageKind, u16, WorkStats)> = Vec::new();
    if error.is_none() {
        flush_seq += 1;
        let req = wire::encode_frame(FrameKind::FlushReq, &wire::encode_qid(flush_seq));
        for p in peers.iter_mut() {
            if let Err(e) = p.send_now(&req) {
                error = Some(format!("barrier send: {e}"));
                break;
            }
        }
        let n_workers = peers.len();
        let mut acks = 0usize;
        while error.is_none() && acks < n_workers {
            match ev_rx.recv_timeout(CONTROL_TIMEOUT) {
                Ok(DriverEv::FlushAck { seq, meter: m, work: w, from }) => {
                    if seq != flush_seq {
                        error = Some(format!(
                            "worker {from} acked barrier {seq}, expected {flush_seq}"
                        ));
                    } else {
                        meter.merge(&m);
                        work.extend(w);
                        acks += 1;
                    }
                }
                Ok(DriverEv::Stopped { from, reason }) => {
                    error = Some(format!("worker {from} stopped at barrier: {reason}"));
                }
                Ok(DriverEv::Closed { from, err }) => {
                    error = Some(format!("worker {from} connection lost at barrier: {err}"));
                }
                // late chatter from the run handle; harmless at a barrier
                Ok(DriverEv::Ingress(_)) | Ok(DriverEv::Finish) => {}
                Ok(_) => error = Some("unexpected frame at stream barrier".into()),
                Err(e) => error = Some(format!("stream barrier: {e}")),
            }
        }
    }
    meter.flush();
    SocketStreamJoin { peers, ev_rx, meter, work, flush_seq, error }
}

/// Deliver queued head-node messages on a streaming run and handle
/// completions: latency from the per-qid dispatch stamp, `Done` acks to
/// every DP host, a gate release, and the completion onto the egress.
#[allow(clippy::too_many_arguments)]
fn drain_local_stream(
    local_q: &mut VecDeque<(Dest, Msg)>,
    ags: &mut [Box<dyn StageHandler>],
    comps: &mut Vec<QueryResult>,
    dispatch_ts: &mut HashMap<u32, Instant>,
    in_flight: &mut usize,
    peers: &mut [PeerConn],
    dp_hosts: &[u16],
    gate: &StreamGate,
    egress: &mpsc::Sender<StreamCompletion>,
) -> std::result::Result<(), String> {
    let mut emitted: Vec<(Dest, Msg)> = Vec::new();
    while let Some((dest, msg)) = local_q.pop_front() {
        if dest.stage != StageKind::Ag {
            return Err(format!("{:?} message addressed to the head node", dest.stage));
        }
        let ag = match ags.get_mut(dest.copy as usize) {
            Some(a) => a,
            None => return Err(format!("no AG copy {}", dest.copy)),
        };
        ag.on_msg(msg, &mut emitted);
        debug_assert!(emitted.is_empty(), "AG emitted a message");
        emitted.clear();
        ag.take_completions(comps);
        for (qid, hits) in comps.drain(..) {
            let secs = dispatch_ts
                .remove(&qid)
                .map(|t| t.elapsed().as_secs_f64())
                .unwrap_or(0.0);
            *in_flight = in_flight.saturating_sub(1);
            // The completion ack: closes the inflight loop and drops the
            // remote per-query dedup state. Control — never metered.
            let done = wire::encode_frame(FrameKind::Done, &wire::encode_qid(qid));
            for &node in dp_hosts {
                if let Err(e) = peers[node as usize].send(&done) {
                    return Err(format!("done ack to worker {node}: {e}"));
                }
            }
            gate.release();
            let _ = egress.send(StreamCompletion { qid, hits, secs });
        }
    }
    Ok(())
}

impl Session {
    fn run_phase(
        &mut self,
        placement: &Placement,
        stages: StageHandlers<'_>,
        workload: Workload<'_>,
    ) -> Result<ExecReport> {
        if self.broken {
            bail!("a previous streaming run on this socket executor failed; relaunch the NetSession");
        }
        if self.stream_open {
            bail!("a streaming run is open on this socket executor; finish it before a phase run");
        }
        if self.peers.len() + 1 != self.placement.total_nodes() {
            bail!(
                "socket executor holds {}/{} worker connections (a streaming run died \
                 without returning them); relaunch the NetSession",
                self.peers.len(),
                self.placement.total_nodes() - 1
            );
        }
        if *placement != self.placement {
            bail!("phase placement differs from the placement workers were launched with");
        }
        let Session { peers, ev_rx, dp_hosts, flush_seq, .. } = self;
        let n_workers = peers.len();
        let head = placement.head_node;
        let n_queries = workload.n_queries;
        let window = workload.window;

        let StageHandlers { head: mut head_stage, bis, dps, mut ags } = stages;
        drop(bis); // BI/DP state lives in the workers, not behind these
        drop(dps);

        let mut meter = TrafficMeter::new(workload.agg_bytes);
        meter.header_bytes = 0; // frames carry their real header in len
        let mut results: Vec<Vec<(f32, u32)>> = vec![Vec::new(); n_queries];
        let mut per_query_secs = vec![0f64; n_queries];
        let mut dispatch_ts: Vec<Instant> = vec![Instant::now(); n_queries];
        let mut local_q: VecDeque<(Dest, Msg)> = VecDeque::new();
        let mut emitted: Vec<(Dest, Msg)> = Vec::new();
        let mut comps: Vec<QueryResult> = Vec::new();
        let mut completed = 0usize;
        let mut in_flight = 0usize;
        let mut items = workload.items.peekable();
        let mut items_done = false;

        loop {
            // Admit while the window allows; items without a qid (index
            // blocks) are never windowed — same policy as the threaded
            // executor.
            while !items_done {
                let next_is_query = match items.peek() {
                    None => {
                        items_done = true;
                        break;
                    }
                    Some(m) => m.qid().is_some(),
                };
                if next_is_query && window != 0 && in_flight >= window {
                    break;
                }
                let item = items.next().expect("peeked non-empty");
                let item_qid = item.qid();
                head_stage.on_msg(item, &mut emitted);
                if let Some(qid) = item_qid {
                    dispatch_ts[qid as usize] = Instant::now();
                    in_flight += 1;
                }
                for (dest, msg) in emitted.drain(..) {
                    let node = placement.node_of(dest.stage, dest.copy);
                    if node == head {
                        meter.send(head, head, 0);
                        local_q.push_back((dest, msg));
                    } else {
                        let frame = wire::stage_frame(dest, &msg);
                        meter.send(head, node, frame.len());
                        peers[node as usize].send(&frame)?;
                    }
                }
                drain_local(
                    &mut local_q,
                    &mut ags,
                    &mut comps,
                    &mut results,
                    &mut per_query_secs,
                    &dispatch_ts,
                    &mut completed,
                    &mut in_flight,
                    peers,
                    dp_hosts,
                )?;
            }
            if items_done && completed >= n_queries {
                break;
            }
            // Block for remote events — but only after everything queued
            // reached the wire, or the closed loop deadlocks.
            for p in peers.iter_mut() {
                p.flush()?;
            }
            match ev_rx.recv_timeout(PHASE_STALL_TIMEOUT) {
                Ok(DriverEv::Msg { dest, msg, .. }) => {
                    local_q.push_back((dest, msg));
                    drain_local(
                        &mut local_q,
                        &mut ags,
                        &mut comps,
                        &mut results,
                        &mut per_query_secs,
                        &dispatch_ts,
                        &mut completed,
                        &mut in_flight,
                        peers,
                        dp_hosts,
                    )?;
                }
                Ok(DriverEv::Stopped { from, reason }) => {
                    bail!("worker {from} stopped mid-phase: {reason}")
                }
                Ok(DriverEv::Closed { from, err }) => {
                    bail!("worker {from} connection lost mid-phase: {err}")
                }
                Ok(_) => bail!("unexpected control frame mid-phase"),
                Err(RecvTimeoutError::Timeout) => bail!(
                    "phase stalled: {completed}/{n_queries} queries after {}s of silence",
                    PHASE_STALL_TIMEOUT.as_secs()
                ),
                Err(RecvTimeoutError::Disconnected) => bail!("all worker readers exited"),
            }
        }

        // Phase barrier: collect every worker's real bytes-on-wire meter
        // plus its per-copy work counters (so the report's work accounting
        // covers the remote BI/DP copies, not just the head).
        *flush_seq += 1;
        let seq = *flush_seq;
        let req = wire::encode_frame(FrameKind::FlushReq, &wire::encode_qid(seq));
        for p in peers.iter_mut() {
            p.send_now(&req)?;
        }
        meter.flush();
        let mut remote_work: Vec<(StageKind, u16, WorkStats)> = Vec::new();
        let mut acks = 0usize;
        while acks < n_workers {
            match ev_rx.recv_timeout(CONTROL_TIMEOUT) {
                Ok(DriverEv::FlushAck { seq: s, meter: m, work, from }) => {
                    if s != seq {
                        bail!("worker {from} acked barrier {s}, expected {seq}");
                    }
                    meter.merge(&m);
                    remote_work.extend(work);
                    acks += 1;
                }
                Ok(DriverEv::Stopped { from, reason }) => {
                    bail!("worker {from} stopped at barrier: {reason}")
                }
                Ok(DriverEv::Closed { from, err }) => {
                    bail!("worker {from} connection lost at barrier: {err}")
                }
                Ok(_) => bail!("unexpected frame at phase barrier"),
                Err(e) => bail!("phase barrier: {e}"),
            }
        }
        Ok(ExecReport { results, per_query_secs, meter, work: remote_work })
    }
}

/// Deliver queued head-node messages (always AG — the head hosts no BI/DP
/// copy) and handle completions: record result + latency, shrink the
/// admission window, and fan the `Done` ack to every DP-hosting worker.
#[allow(clippy::too_many_arguments)]
fn drain_local(
    local_q: &mut VecDeque<(Dest, Msg)>,
    ags: &mut [Box<dyn StageHandler + '_>],
    comps: &mut Vec<QueryResult>,
    results: &mut [Vec<(f32, u32)>],
    per_query_secs: &mut [f64],
    dispatch_ts: &[Instant],
    completed: &mut usize,
    in_flight: &mut usize,
    peers: &mut [PeerConn],
    dp_hosts: &[u16],
) -> Result<()> {
    let mut emitted: Vec<(Dest, Msg)> = Vec::new();
    while let Some((dest, msg)) = local_q.pop_front() {
        if dest.stage != StageKind::Ag {
            bail!("{:?} message addressed to the head node", dest.stage);
        }
        let ag = ags
            .get_mut(dest.copy as usize)
            .ok_or_else(|| anyhow!("no AG copy {}", dest.copy))?;
        ag.on_msg(msg, &mut emitted);
        debug_assert!(emitted.is_empty(), "AG emitted a message");
        emitted.clear();
        ag.take_completions(comps);
        for (qid, hits) in comps.drain(..) {
            per_query_secs[qid as usize] =
                dispatch_ts[qid as usize].elapsed().as_secs_f64();
            results[qid as usize] = hits;
            *completed += 1;
            *in_flight = in_flight.saturating_sub(1);
            // The completion ack: closes the inflight loop and drops the
            // remote per-query dedup state. Control — never metered.
            let done = wire::encode_frame(FrameKind::Done, &wire::encode_qid(qid));
            for &node in dp_hosts {
                peers[node as usize].send(&done)?;
            }
        }
    }
    Ok(())
}

/// A running multi-process cluster: worker children + the socket executor.
/// Shut it down explicitly with [`NetSession::shutdown`] for a typed exit;
/// dropping the session kills any still-running workers (no leaks either
/// way).
pub struct NetSession {
    children: Vec<Child>,
    exec: SocketExecutor,
}

impl NetSession {
    /// Launch workers using this very binary's `worker` subcommand (the
    /// normal path for `parlsh` itself). Override the binary with the
    /// `PARLSH_WORKER_BIN` env var when the current executable is not
    /// `parlsh` (e.g. a test harness).
    pub fn launch(cfg: &Config, dim: usize) -> Result<NetSession> {
        let bin = match std::env::var("PARLSH_WORKER_BIN") {
            Ok(p) => std::path::PathBuf::from(p),
            Err(_) => std::env::current_exe().context("resolve current executable")?,
        };
        Self::launch_with_bin(&bin, cfg, dim)
    }

    /// Launch one worker process per BI/DP node of `cfg.cluster` from an
    /// explicit binary path, connect, and handshake. `dim` is the dataset
    /// dimensionality workers size their DP stores with.
    pub fn launch_with_bin(bin: &Path, cfg: &Config, dim: usize) -> Result<NetSession> {
        let placement = Placement::new(&cfg.cluster);
        let n_workers = placement.total_nodes() - 1;
        // Every worker binds the same configured address, so a fixed port
        // can only ever host one worker — reject it up front instead of
        // letting worker 1 die on EADDRINUSE before announcing itself.
        if n_workers > 1 && !cfg.sock.listen.ends_with(":0") {
            bail!(
                "net.listen `{}` pins a port but {n_workers} workers must bind it; \
                 use port 0 (OS-assigned) for local multi-worker launches",
                cfg.sock.listen
            );
        }
        let placeholder = mpsc::sync_channel(1);
        let mut session = NetSession {
            children: Vec::with_capacity(n_workers),
            exec: SocketExecutor {
                inner: Mutex::new(Session {
                    peers: Vec::new(),
                    ev_tx: placeholder.0, // replaced below
                    ev_rx: placeholder.1,
                    placement: placement.clone(),
                    dp_hosts: (cfg.cluster.bi_nodes
                        ..cfg.cluster.bi_nodes + cfg.cluster.dp_nodes)
                        .map(|n| n as u16)
                        .collect(),
                    flush_seq: 0,
                    stream_open: false,
                    broken: false,
                }),
            },
        };

        // Spawn first, then read each announced listen address. Workers
        // must not write anything else to stdout.
        for node in 0..n_workers {
            let child = Command::new(bin)
                .arg("worker")
                .arg(format!("--listen={}", cfg.sock.listen))
                .arg("--set")
                .arg(format!("net.max_frame_bytes={}", cfg.sock.max_frame_bytes))
                .arg("--set")
                .arg(format!("net.connect_retries={}", cfg.sock.connect_retries))
                .arg("--set")
                .arg(format!("net.retry_ms={}", cfg.sock.retry_ms))
                .arg("--set")
                .arg(format!("net.queue_frames={}", cfg.sock.queue_frames))
                .stdin(Stdio::null())
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit())
                .spawn()
                .with_context(|| format!("spawn worker {node} from {}", bin.display()))?;
            session.children.push(child);
        }
        let mut addrs = Vec::with_capacity(n_workers);
        for (node, child) in session.children.iter_mut().enumerate() {
            let stdout = child.stdout.take().expect("piped stdout");
            let mut line = String::new();
            BufReader::new(stdout)
                .read_line(&mut line)
                .with_context(|| format!("read worker {node} listen line"))?;
            let addr = line
                .trim()
                .strip_prefix("PARLSH_WORKER_LISTEN ")
                .ok_or_else(|| anyhow!("worker {node} announced `{}`", line.trim()))?
                .to_string();
            addrs.push(addr);
        }

        // Connect + handshake each worker; reader threads feed one
        // *bounded* channel (`net.queue_frames`, see the module docs for
        // the closed-loop-vs-open-loop depth argument).
        let digest = wire::config_digest(dim as u32, &cfg.lsh, &cfg.cluster, &cfg.stream);
        let (ev_tx, ev_rx) = mpsc::sync_channel::<DriverEv>(cfg.sock.queue_frames.max(1));
        let mut peers = Vec::with_capacity(n_workers);
        for node in 0..n_workers {
            let stream = connect_retry(
                &addrs[node],
                cfg.sock.connect_retries,
                cfg.sock.retry_ms,
            )
            .with_context(|| format!("connect worker {node} at {}", addrs[node]))?;
            // Writes that stall past the phase-stall horizon fail loudly
            // (typed IO error → phase/stream error) instead of hanging:
            // with the bounded reader queues a fully-wedged
            // backpressure cycle is theoretically reachable under
            // extreme open-loop pressure, and a blocked write has no
            // recv-side stall clock to save it (see the module docs).
            stream.set_write_timeout(Some(PHASE_STALL_TIMEOUT)).ok();
            let reader = stream.try_clone().context("clone worker conn")?;
            spawn_reader(reader, node as u16, ev_tx.clone(), cfg.sock.max_frame_bytes);
            let mut pc = PeerConn::new(stream, cfg.stream.agg_bytes);
            let hello = Hello {
                node: node as u16,
                dim: dim as u32,
                peers: addrs.clone(),
                lsh: cfg.lsh,
                cluster: cfg.cluster,
                stream: cfg.stream,
                digest,
            };
            pc.send_now(&wire::encode_frame(FrameKind::Hello, &wire::encode_hello(&hello)))?;
            peers.push(pc);
        }

        // Every worker must accept the same config digest before any
        // workload flows.
        let mut ok = vec![false; n_workers];
        let mut acked = 0usize;
        while acked < n_workers {
            match ev_rx.recv_timeout(CONTROL_TIMEOUT) {
                Ok(DriverEv::HelloOk { from, node, digest: d }) => {
                    if node != from {
                        bail!("worker on conn {from} claims node {node}");
                    }
                    if d != digest {
                        bail!("worker {from} config digest mismatch");
                    }
                    if std::mem::replace(&mut ok[from as usize], true) {
                        bail!("worker {from} acked twice");
                    }
                    acked += 1;
                }
                Ok(DriverEv::Stopped { from, reason }) => {
                    bail!("worker {from} stopped during handshake: {reason}")
                }
                Ok(DriverEv::Closed { from, err }) => {
                    bail!("worker {from} closed during handshake: {err}")
                }
                Ok(_) => bail!("unexpected frame during handshake"),
                Err(e) => bail!("handshake: {e}"),
            }
        }

        {
            let inner = session.exec.inner.get_mut().unwrap_or_else(|p| p.into_inner());
            inner.peers = peers;
            inner.ev_rx = ev_rx;
            inner.ev_tx = ev_tx;
        }
        Ok(session)
    }

    /// The executor to pass to `build_index_on` / `search_on`.
    pub fn executor(&self) -> &SocketExecutor {
        &self.exec
    }

    /// Snapshot every worker's BI buckets and DP objects (differential
    /// tests; one `(node, state)` pair per worker, node-sorted).
    pub fn fetch_state(&self) -> Result<Vec<(u16, NodeState)>> {
        let mut s = self.exec.inner.lock().unwrap_or_else(|p| p.into_inner());
        if s.broken {
            bail!("a previous streaming run on this socket executor failed; relaunch the NetSession");
        }
        if s.stream_open {
            bail!("a streaming run is open; finish it before snapshotting worker state");
        }
        if s.peers.len() + 1 != s.placement.total_nodes() {
            bail!(
                "socket executor holds {}/{} worker connections (a streaming run died \
                 without returning them)",
                s.peers.len(),
                s.placement.total_nodes() - 1
            );
        }
        let Session { peers, ev_rx, .. } = &mut *s;
        let req = wire::encode_frame(FrameKind::StateReq, &[]);
        for p in peers.iter_mut() {
            p.send_now(&req)?;
        }
        let mut out = Vec::with_capacity(peers.len());
        while out.len() < peers.len() {
            match ev_rx.recv_timeout(CONTROL_TIMEOUT) {
                Ok(DriverEv::State { from, state }) => out.push((from, state)),
                Ok(DriverEv::Stopped { from, reason }) => {
                    bail!("worker {from} stopped during snapshot: {reason}")
                }
                Ok(DriverEv::Closed { from, err }) => {
                    bail!("worker {from} closed during snapshot: {err}")
                }
                Ok(_) => bail!("unexpected frame during snapshot"),
                Err(e) => bail!("state snapshot: {e}"),
            }
        }
        out.sort_by_key(|(node, _)| *node);
        Ok(out)
    }

    /// Typed shutdown: ask every worker to exit, then join them all,
    /// failing on any nonzero exit. Workers that ignore the request are
    /// killed (and reported) rather than leaked.
    pub fn shutdown(mut self) -> Result<()> {
        {
            let mut s = self.exec.inner.lock().unwrap_or_else(|p| p.into_inner());
            if s.stream_open {
                bail!("a streaming run is open; finish it before shutting the workers down");
            }
            if s.peers.len() + 1 != s.placement.total_nodes() {
                bail!(
                    "socket executor holds {}/{} worker connections (a streaming run died \
                     without returning them); workers will be killed, not joined",
                    s.peers.len(),
                    s.placement.total_nodes() - 1
                );
            }
            let frame = wire::encode_frame(FrameKind::Shutdown, &[]);
            for p in s.peers.iter_mut() {
                p.send_now(&frame)?;
            }
        }
        let mut children = std::mem::take(&mut self.children);
        for (node, child) in children.iter_mut().enumerate() {
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                match child.try_wait().with_context(|| format!("wait worker {node}"))? {
                    Some(status) if status.success() => break,
                    Some(status) => bail!("worker {node} exited with {status}"),
                    None if Instant::now() >= deadline => {
                        child.kill().ok();
                        child.wait().ok();
                        bail!("worker {node} ignored shutdown; killed");
                    }
                    None => std::thread::sleep(Duration::from_millis(10)),
                }
            }
        }
        Ok(())
    }
}

impl Drop for NetSession {
    fn drop(&mut self) {
        // Error paths only: `shutdown` drains `children` first.
        for child in &mut self.children {
            child.kill().ok();
            child.wait().ok();
        }
    }
}

fn spawn_reader(stream: TcpStream, from: u16, tx: SyncSender<DriverEv>, max_frame: usize) {
    std::thread::spawn(move || reader_loop(stream, from, tx, max_frame));
}

/// One reader per worker connection. The `tx` channel is bounded: a full
/// driver queue blocks this thread, which stops draining the socket and
/// backpressures the worker's TCP sender (see the module docs).
fn reader_loop(mut stream: TcpStream, from: u16, tx: SyncSender<DriverEv>, max_frame: usize) {
    loop {
        let frame = match wire::read_frame(&mut stream, max_frame) {
            Ok(f) => f,
            Err(e) => {
                let _ = tx.send(DriverEv::Closed { from, err: e.to_string() });
                return;
            }
        };
        let ev = match frame.kind {
            FrameKind::HelloOk => wire::decode_hello_ok(&frame.payload)
                .map(|(node, digest)| DriverEv::HelloOk { from, node, digest }),
            FrameKind::Stage => wire::decode_stage(&frame.payload)
                .map(|(dest, msg)| DriverEv::Msg { from, dest, msg }),
            FrameKind::FlushAck => wire::decode_flush_ack(&frame.payload)
                .map(|(seq, meter, work)| DriverEv::FlushAck { from, seq, meter, work }),
            FrameKind::StateDump => wire::decode_state_dump(&frame.payload)
                .map(|state| DriverEv::State { from, state }),
            FrameKind::Stopped => wire::decode_stopped(&frame.payload)
                .map(|reason| DriverEv::Stopped { from, reason }),
            other => Err(anyhow!("unexpected frame {other:?} from worker {from}")),
        };
        match ev {
            Ok(ev) => {
                let stop = matches!(ev, DriverEv::Stopped { .. });
                if tx.send(ev).is_err() || stop {
                    return;
                }
            }
            Err(e) => {
                let _ = tx.send(DriverEv::Closed { from, err: e.to_string() });
                return;
            }
        }
    }
}
