//! The versioned, length-framed binary wire format of the socket transport
//! (DESIGN.md §Transports).
//!
//! Every frame is `header (12 bytes) + payload`:
//!
//! ```text
//! magic   u16  0x504C ("PL")
//! version u8   WIRE_VERSION
//! kind    u8   FrameKind
//! len     u32  payload length
//! crc     u32  FNV-1a over header[0..8] + payload
//! ```
//!
//! The checksum covers the kind and length bytes as well as the payload, so
//! any single corrupted byte is rejected at [`read_frame`] rather than
//! misrouted. Payload encodings are little-endian, length-prefixed, and
//! strict: decoders reject trailing bytes, truncated fields, and length
//! prefixes that exceed the remaining buffer (no attacker-sized
//! allocations). `f32` travels as its bit pattern, so vector payloads —
//! and therefore distributed top-k results — roundtrip bit-exactly.
//!
//! Frame kinds: [`FrameKind::Stage`] carries one routed dataflow [`Msg`];
//! everything else is control — the `Hello`/`HelloOk` handshake (config +
//! placement + digest), `PeerHello` (worker→worker identification), `Done`
//! (query-completion ack closing the `stream.inflight` loop and tearing
//! down DP dedup state), `FlushReq`/`FlushAck` (phase barrier carrying the
//! worker's real bytes-on-wire [`TrafficMeter`]), `StateReq`/`StateDump`
//! (differential-test snapshots), and the typed `Stopped`/`Shutdown` pair
//! mirroring the threaded executor's drop-guard semantics. `Completion`
//! is the front-door result frame (server → external client): the
//! client's qid, the resolved option echo, and the exact top-k hits.

use crate::config::{ClusterConfig, ObjMapStrategy, ReplicaRoute, StreamConfig};
use crate::core::lsh::LshParams;
use crate::dataflow::message::{Dest, Msg, QueryOptions, StageKind};
use crate::dataflow::metrics::{TrafficMeter, WorkStats};
use crate::stages::{BiState, DpState};
use anyhow::{anyhow, bail, Context, Result};
use std::fmt;
use std::io::Read;
use std::sync::Arc;

// v6: storage-engine counters — FlushAck work entries gain the
// `bucket_skipped` counter and the `bytes_resident` gauge, 75 → 91 bytes
// per entry (DESIGN.md §Storage engine). (v5 added the replicated cluster
// topology — session epochs on Hello/HelloOk, the
// `cluster.{replication,replica_route}` config block, and seven control
// kinds: Ping/Pong, Restore/RestoreOk, Membership, PersistReq/PersistOk;
// v4 added the `dists_pruned` WorkStats counter, 67 → 75 bytes per
// FlushAck entry; v3 added per-query search plans — QueryVec carries
// QueryOptions, Query/CandidateReq/QueryMeta carry the resolved k; v2
// added per-copy WorkStats to FlushAck.)
pub const WIRE_VERSION: u8 = 6;
pub const MAGIC: u16 = 0x504C;
pub const HEADER_LEN: usize = 12;

/// Typed frame-level decode failure, surfaced by [`read_frame`]. Callers
/// that only report can `Display` it; version-negotiation logic can match
/// on [`WireError::VersionMismatch`] — a v3 (or any non-v4) frame is a
/// *typed* rejection, never a panic and never a misparse.
#[derive(Debug)]
pub enum WireError {
    /// Underlying IO failed (`what` names the read that failed).
    Io { what: &'static str, err: std::io::Error },
    /// First two header bytes are not the `PL` magic.
    BadMagic(u16),
    /// Peer speaks a different wire version (e.g. a v2 worker).
    VersionMismatch { got: u8, want: u8 },
    /// Unknown frame-kind byte.
    UnknownKind(u8),
    /// Declared payload length exceeds the configured cap.
    Oversize { len: usize, cap: usize },
    /// FNV checksum over header+payload did not match.
    Checksum { got: u32, want: u32 },
    /// A (re)joining worker announced a config digest that is not this
    /// session's — it was built against different parameters.
    DigestMismatch { got: u64, want: u64 },
    /// A rejoining worker's shard epoch is neither current nor empty —
    /// admitting it would serve stale data into a live stream.
    EpochFenced { got: u64, want: u64 },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Io { what, err } => write!(f, "{what}: {err}"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:#06x}"),
            WireError::VersionMismatch { got, want } => {
                write!(f, "wire version {got} (want {want})")
            }
            WireError::UnknownKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::Oversize { len, cap } => {
                write!(f, "frame of {len} bytes exceeds cap {cap}")
            }
            WireError::Checksum { got, want } => {
                write!(f, "frame checksum mismatch (got {got:#010x}, want {want:#010x})")
            }
            WireError::DigestMismatch { got, want } => {
                write!(f, "join config digest mismatch (got {got:#018x}, want {want:#018x})")
            }
            WireError::EpochFenced { got, want } => {
                write!(f, "stale epoch {got} fenced (session at epoch {want})")
            }
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io { err, .. } => Some(err),
            _ => None,
        }
    }
}

/// What a frame carries. Discriminants are the on-wire kind byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Driver → worker: handshake (node id, dim, peer table, config, digest).
    Hello = 0,
    /// Worker → driver: handshake accepted (echoes the config digest).
    HelloOk = 1,
    /// Worker → worker: identifies the sending node on a fresh connection.
    PeerHello = 2,
    /// A routed dataflow message: `Dest` + `Msg`.
    Stage = 3,
    /// Driver → worker: query completed (admission-window ack; DP teardown).
    Done = 4,
    /// Driver → worker: phase barrier; reply with `FlushAck`.
    FlushReq = 5,
    /// Worker → driver: barrier ack carrying the worker's traffic meter.
    FlushAck = 6,
    /// Driver → worker: request a state snapshot of all hosted copies.
    StateReq = 7,
    /// Worker → driver: BI bucket + DP object snapshots.
    StateDump = 8,
    /// Either direction: typed failure notice (the drop-guard frame).
    Stopped = 9,
    /// Driver → worker: exit cleanly.
    Shutdown = 10,
    /// Front server → external client: one finished query (qid in the
    /// *client's* namespace, resolved option echo, exact top-k hits).
    Completion = 11,
    /// Driver → worker: liveness probe (empty payload); reply with `Pong`.
    Ping = 12,
    /// Worker → driver: liveness reply carrying the worker's epoch.
    Pong = 13,
    /// Driver → rejoined worker: shard transfer (epoch + state dump).
    Restore = 14,
    /// Worker → driver: shard replayed; carries the worker's slot id.
    RestoreOk = 15,
    /// Driver → worker: the live mask + address per slot, stamped with the
    /// session epoch, so worker→worker routing agrees with the driver's.
    Membership = 16,
    /// Driver → worker: checkpoint your shard to the given path.
    PersistReq = 17,
    /// Worker → driver: shard checkpointed; carries the worker's slot id.
    PersistOk = 18,
}

impl FrameKind {
    pub fn from_u8(v: u8) -> Option<FrameKind> {
        use FrameKind::*;
        match v {
            0 => Some(Hello),
            1 => Some(HelloOk),
            2 => Some(PeerHello),
            3 => Some(Stage),
            4 => Some(Done),
            5 => Some(FlushReq),
            6 => Some(FlushAck),
            7 => Some(StateReq),
            8 => Some(StateDump),
            9 => Some(Stopped),
            10 => Some(Shutdown),
            11 => Some(Completion),
            12 => Some(Ping),
            13 => Some(Pong),
            14 => Some(Restore),
            15 => Some(RestoreOk),
            16 => Some(Membership),
            17 => Some(PersistReq),
            18 => Some(PersistOk),
            _ => None,
        }
    }
}

/// A decoded frame: kind + raw payload (decode with the `decode_*` fns).
#[derive(Debug)]
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Vec<u8>,
}

// ------------------------------------------------------------ primitives

fn fnv1a32(seed: u32, bytes: &[u8]) -> u32 {
    let mut h = seed;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}
const FNV_OFFSET: u32 = 0x811C_9DC5;

/// FNV-1a 64 — public because replica routing (`net::cluster::replica`)
/// hashes query vectors with it; both ends of every connection must agree
/// on the function bit-for-bit.
pub fn fnv1a64(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = seed;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}
pub const FNV64_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;

fn put_u8(b: &mut Vec<u8>, v: u8) {
    b.push(v);
}
fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_f32(b: &mut Vec<u8>, v: f32) {
    put_u32(b, v.to_bits());
}
fn put_str(b: &mut Vec<u8>, s: &str) {
    assert!(s.len() <= u16::MAX as usize, "string too long for wire");
    put_u16(b, s.len() as u16);
    b.extend_from_slice(s.as_bytes());
}
fn put_f32s(b: &mut Vec<u8>, xs: &[f32]) {
    put_u32(b, xs.len() as u32);
    for &x in xs {
        put_f32(b, x);
    }
}

/// Bounds-checked little-endian reader over a payload slice.
struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Rd<'a> {
        Rd { b, pos: 0 }
    }
    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("truncated payload: need {n} bytes, have {}", self.remaining());
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    /// Length prefix for elements of `elem` bytes each, alloc-bounded by
    /// the remaining buffer.
    fn len_prefix(&mut self, elem: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem) > self.remaining() {
            bail!("length prefix {n} exceeds remaining payload");
        }
        Ok(n)
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len_prefix(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        let bytes = self.take(n)?;
        Ok(std::str::from_utf8(bytes).context("non-utf8 string")?.to_string())
    }
    fn done(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("{} trailing bytes after payload", self.remaining());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- framing

/// Wrap a payload in a checksummed frame. Panics loudly on a payload the
/// u32 length field cannot represent — wrapping would emit a frame whose
/// declared length lies, surfacing far away as a checksum mismatch.
pub fn encode_frame(kind: FrameKind, payload: &[u8]) -> Vec<u8> {
    assert!(
        payload.len() <= u32::MAX as usize,
        "frame payload of {} bytes exceeds the u32 length field",
        payload.len()
    );
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    put_u16(&mut out, MAGIC);
    put_u8(&mut out, WIRE_VERSION);
    put_u8(&mut out, kind as u8);
    put_u32(&mut out, payload.len() as u32);
    let crc = fnv1a32(fnv1a32(FNV_OFFSET, &out[0..8]), payload);
    put_u32(&mut out, crc);
    out.extend_from_slice(payload);
    out
}

/// Read and verify one frame. Errors (typed, [`WireError`]) on EOF, bad
/// magic, a version other than [`WIRE_VERSION`] (v2 peers are rejected
/// here, per frame — and at the handshake digest, which covers the
/// version), an unknown kind, a length above `max_frame`, or a checksum
/// mismatch.
pub fn read_frame(r: &mut dyn Read, max_frame: usize) -> std::result::Result<Frame, WireError> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr)
        .map_err(|err| WireError::Io { what: "read frame header", err })?;
    let magic = u16::from_le_bytes([hdr[0], hdr[1]]);
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    if hdr[2] != WIRE_VERSION {
        return Err(WireError::VersionMismatch { got: hdr[2], want: WIRE_VERSION });
    }
    let kind = FrameKind::from_u8(hdr[3]).ok_or(WireError::UnknownKind(hdr[3]))?;
    let len = u32::from_le_bytes([hdr[4], hdr[5], hdr[6], hdr[7]]) as usize;
    if len > max_frame {
        return Err(WireError::Oversize { len, cap: max_frame });
    }
    let crc = u32::from_le_bytes([hdr[8], hdr[9], hdr[10], hdr[11]]);
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|err| WireError::Io { what: "read frame payload", err })?;
    let want = fnv1a32(fnv1a32(FNV_OFFSET, &hdr[0..8]), &payload);
    if crc != want {
        return Err(WireError::Checksum { got: crc, want });
    }
    Ok(Frame { kind, payload })
}

// ------------------------------------------------------------ Msg codec

fn obj_map_code(s: ObjMapStrategy) -> u8 {
    match s {
        ObjMapStrategy::Mod => 0,
        ObjMapStrategy::ZOrder => 1,
        ObjMapStrategy::Lsh => 2,
    }
}

fn obj_map_from_code(c: u8) -> Result<ObjMapStrategy> {
    match c {
        0 => Ok(ObjMapStrategy::Mod),
        1 => Ok(ObjMapStrategy::ZOrder),
        2 => Ok(ObjMapStrategy::Lsh),
        _ => bail!("unknown obj_map code {c}"),
    }
}

fn replica_route_code(r: ReplicaRoute) -> u8 {
    match r {
        ReplicaRoute::RoundRobin => 0,
        ReplicaRoute::Layered => 1,
    }
}

fn replica_route_from_code(c: u8) -> Result<ReplicaRoute> {
    match c {
        0 => Ok(ReplicaRoute::RoundRobin),
        1 => Ok(ReplicaRoute::Layered),
        _ => bail!("unknown replica_route code {c}"),
    }
}

// QueryOptions default-elision flags (wire v3): one flags byte, then a
// u32 per set bit in bit order. An unset field decodes to 0 — the
// "inherit the config" sentinel — so the all-default plan costs 1 byte.
const OPT_K: u8 = 1 << 0;
const OPT_PROBES: u8 = 1 << 1;
const OPT_TABLES: u8 = 1 << 2;
const OPT_TAG: u8 = 1 << 3;
const OPT_ALL: u8 = OPT_K | OPT_PROBES | OPT_TABLES | OPT_TAG;

fn put_opts(b: &mut Vec<u8>, o: &QueryOptions) {
    let mut flags = 0u8;
    for (bit, v) in [
        (OPT_K, o.k),
        (OPT_PROBES, o.probes),
        (OPT_TABLES, o.tables),
        (OPT_TAG, o.tag),
    ] {
        if v != 0 {
            flags |= bit;
        }
    }
    put_u8(b, flags);
    for (bit, v) in [
        (OPT_K, o.k),
        (OPT_PROBES, o.probes),
        (OPT_TABLES, o.tables),
        (OPT_TAG, o.tag),
    ] {
        if flags & bit != 0 {
            put_u32(b, v);
        }
    }
}

fn read_opts(rd: &mut Rd<'_>) -> Result<QueryOptions> {
    let flags = rd.u8()?;
    if flags & !OPT_ALL != 0 {
        bail!("unknown QueryOptions flags {flags:#04x}");
    }
    let mut o = QueryOptions::default();
    if flags & OPT_K != 0 {
        o.k = rd.u32()?;
    }
    if flags & OPT_PROBES != 0 {
        o.probes = rd.u32()?;
    }
    if flags & OPT_TABLES != 0 {
        o.tables = rd.u32()?;
    }
    if flags & OPT_TAG != 0 {
        o.tag = rd.u32()?;
    }
    Ok(o)
}

/// Encode a routed stage message as a complete frame (header included).
pub fn stage_frame(dest: Dest, msg: &Msg) -> Vec<u8> {
    let mut p = Vec::with_capacity(16 + msg.wire_size());
    put_u8(&mut p, dest.stage.code());
    put_u16(&mut p, dest.copy);
    match msg {
        Msg::IndexBlock { id_base, rows, flat } => {
            put_u8(&mut p, 0);
            put_u32(&mut p, *id_base);
            put_u32(&mut p, *rows);
            put_f32s(&mut p, flat);
        }
        Msg::QueryVec { qid, raw, v, opts } => {
            put_u8(&mut p, 1);
            put_u32(&mut p, *qid);
            put_opts(&mut p, opts);
            put_f32s(&mut p, raw);
            put_f32s(&mut p, v);
        }
        Msg::StoreObject { id, v } => {
            put_u8(&mut p, 2);
            put_u32(&mut p, *id);
            put_f32s(&mut p, v);
        }
        Msg::IndexRef { table, key, id, dp } => {
            put_u8(&mut p, 3);
            put_u8(&mut p, *table);
            put_u64(&mut p, *key);
            put_u32(&mut p, *id);
            put_u16(&mut p, *dp);
        }
        Msg::Query { qid, probes, v, k } => {
            put_u8(&mut p, 4);
            put_u32(&mut p, *qid);
            put_u32(&mut p, *k);
            put_u32(&mut p, probes.len() as u32);
            for &(table, key) in probes {
                put_u8(&mut p, table);
                put_u64(&mut p, key);
            }
            put_f32s(&mut p, v);
        }
        Msg::CandidateReq { qid, ids, v, k } => {
            put_u8(&mut p, 5);
            put_u32(&mut p, *qid);
            put_u32(&mut p, *k);
            put_u32(&mut p, ids.len() as u32);
            for &id in ids {
                put_u32(&mut p, id);
            }
            put_f32s(&mut p, v);
        }
        Msg::QueryMeta { qid, n_bi, k } => {
            put_u8(&mut p, 6);
            put_u32(&mut p, *qid);
            put_u32(&mut p, *n_bi);
            put_u32(&mut p, *k);
        }
        Msg::BiMeta { qid, n_dp } => {
            put_u8(&mut p, 7);
            put_u32(&mut p, *qid);
            put_u32(&mut p, *n_dp);
        }
        Msg::LocalTopK { qid, hits } => {
            put_u8(&mut p, 8);
            put_u32(&mut p, *qid);
            put_u32(&mut p, hits.len() as u32);
            for &(d, id) in hits {
                put_f32(&mut p, d);
                put_u32(&mut p, id);
            }
        }
    }
    encode_frame(FrameKind::Stage, &p)
}

/// Decode a `Stage` frame payload back into `(Dest, Msg)`.
pub fn decode_stage(payload: &[u8]) -> Result<(Dest, Msg)> {
    let mut rd = Rd::new(payload);
    let stage = StageKind::from_code(rd.u8()?)
        .ok_or_else(|| anyhow!("unknown stage code"))?;
    let copy = rd.u16()?;
    let dest = Dest { stage, copy };
    let tag = rd.u8()?;
    let msg = match tag {
        0 => {
            let id_base = rd.u32()?;
            let rows = rd.u32()?;
            let flat: Arc<[f32]> = rd.f32s()?.into();
            Msg::IndexBlock { id_base, rows, flat }
        }
        1 => {
            let qid = rd.u32()?;
            let opts = read_opts(&mut rd)?;
            let raw: Arc<[f32]> = rd.f32s()?.into();
            let v: Arc<[f32]> = rd.f32s()?.into();
            Msg::QueryVec { qid, raw, v, opts }
        }
        2 => {
            let id = rd.u32()?;
            let v: Arc<[f32]> = rd.f32s()?.into();
            Msg::StoreObject { id, v }
        }
        3 => {
            let table = rd.u8()?;
            let key = rd.u64()?;
            let id = rd.u32()?;
            let dp = rd.u16()?;
            Msg::IndexRef { table, key, id, dp }
        }
        4 => {
            let qid = rd.u32()?;
            let k = rd.u32()?;
            let n = rd.len_prefix(9)?;
            let mut probes = Vec::with_capacity(n);
            for _ in 0..n {
                let table = rd.u8()?;
                let key = rd.u64()?;
                probes.push((table, key));
            }
            let v: Arc<[f32]> = rd.f32s()?.into();
            Msg::Query { qid, probes, v, k }
        }
        5 => {
            let qid = rd.u32()?;
            let k = rd.u32()?;
            let n = rd.len_prefix(4)?;
            let mut ids = Vec::with_capacity(n);
            for _ in 0..n {
                ids.push(rd.u32()?);
            }
            let v: Arc<[f32]> = rd.f32s()?.into();
            Msg::CandidateReq { qid, ids, v, k }
        }
        6 => {
            let qid = rd.u32()?;
            let n_bi = rd.u32()?;
            let k = rd.u32()?;
            Msg::QueryMeta { qid, n_bi, k }
        }
        7 => {
            let qid = rd.u32()?;
            let n_dp = rd.u32()?;
            Msg::BiMeta { qid, n_dp }
        }
        8 => {
            let qid = rd.u32()?;
            let n = rd.len_prefix(8)?;
            let mut hits = Vec::with_capacity(n);
            for _ in 0..n {
                let d = rd.f32()?;
                let id = rd.u32()?;
                hits.push((d, id));
            }
            Msg::LocalTopK { qid, hits }
        }
        _ => bail!("unknown message tag {tag}"),
    };
    rd.done()?;
    Ok((dest, msg))
}

// ------------------------------------------------------------- handshake

/// The driver→worker handshake: which node this process is, the dataset
/// dimensionality, where every worker listens, and the config slice the
/// worker needs to reconstruct its stage copies. The digest covers the
/// encoded config block; the worker echoes it in `HelloOk` so launcher and
/// worker prove they agree on parameters (and on this codec version).
#[derive(Clone, Debug)]
pub struct Hello {
    /// The worker *slot* this process serves (replica-major layout; with
    /// `cluster.replication == 1` this is the logical node id). The front
    /// door reuses the field for the client's admission lane.
    pub node: u16,
    /// Session epoch (completed write phases) at handshake time. A worker
    /// echoes its *own* shard epoch in `HelloOk`; the driver fences the
    /// difference (`net::cluster::membership::validate_join`).
    pub epoch: u64,
    pub dim: u32,
    /// Listen address per worker slot (`0..total_slots()`).
    pub peers: Vec<String>,
    pub lsh: LshParams,
    pub cluster: ClusterConfig,
    pub stream: StreamConfig,
    /// Filled on decode (and by [`config_digest`] on the driver side).
    pub digest: u64,
}

fn encode_cfg_block(dim: u32, lsh: &LshParams, cluster: &ClusterConfig, stream: &StreamConfig) -> Vec<u8> {
    let mut b = Vec::with_capacity(96);
    // The digest covers the wire version itself (v3): a peer speaking an
    // older codec that somehow got past the per-frame version check can
    // never agree on the handshake digest either.
    put_u8(&mut b, WIRE_VERSION);
    put_u32(&mut b, dim);
    put_u32(&mut b, lsh.l as u32);
    put_u32(&mut b, lsh.m as u32);
    put_f32(&mut b, lsh.w);
    put_u32(&mut b, lsh.k as u32);
    put_u32(&mut b, lsh.t as u32);
    put_u64(&mut b, lsh.seed);
    put_u32(&mut b, cluster.bi_nodes as u32);
    put_u32(&mut b, cluster.dp_nodes as u32);
    put_u32(&mut b, cluster.cores_per_node as u32);
    put_u32(&mut b, cluster.ag_copies as u32);
    put_u8(&mut b, cluster.per_core_copies as u8);
    put_u32(&mut b, cluster.replication as u32);
    put_u8(&mut b, replica_route_code(cluster.replica_route));
    put_u8(&mut b, obj_map_code(stream.obj_map));
    put_u64(&mut b, stream.agg_bytes as u64);
    put_u8(&mut b, stream.dedup as u8);
    put_u64(&mut b, stream.max_candidates as u64);
    put_u64(&mut b, stream.inflight as u64);
    b
}

/// The digest both ends must agree on before any workload flows.
pub fn config_digest(dim: u32, lsh: &LshParams, cluster: &ClusterConfig, stream: &StreamConfig) -> u64 {
    fnv1a64(FNV64_OFFSET, &encode_cfg_block(dim, lsh, cluster, stream))
}

pub fn encode_hello(h: &Hello) -> Vec<u8> {
    let mut p = Vec::new();
    put_u16(&mut p, h.node);
    put_u64(&mut p, h.epoch);
    put_u16(&mut p, h.peers.len() as u16);
    for addr in &h.peers {
        put_str(&mut p, addr);
    }
    let cfg = encode_cfg_block(h.dim, &h.lsh, &h.cluster, &h.stream);
    put_u32(&mut p, cfg.len() as u32);
    p.extend_from_slice(&cfg);
    put_u64(&mut p, fnv1a64(FNV64_OFFSET, &cfg));
    p
}

pub fn decode_hello(payload: &[u8]) -> Result<Hello> {
    let mut rd = Rd::new(payload);
    let node = rd.u16()?;
    let epoch = rd.u64()?;
    let n_peers = rd.u16()? as usize;
    let mut peers = Vec::with_capacity(n_peers.min(rd.remaining() / 2));
    for _ in 0..n_peers {
        peers.push(rd.str()?);
    }
    let cfg_len = rd.u32()? as usize;
    let cfg = rd.take(cfg_len)?.to_vec();
    let digest = rd.u64()?;
    rd.done()?;
    if digest != fnv1a64(FNV64_OFFSET, &cfg) {
        bail!("handshake config digest mismatch");
    }
    let mut c = Rd::new(&cfg);
    let ver = c.u8()?;
    if ver != WIRE_VERSION {
        bail!("handshake config block for wire version {ver} (want {WIRE_VERSION})");
    }
    let dim = c.u32()?;
    let lsh = LshParams {
        l: c.u32()? as usize,
        m: c.u32()? as usize,
        w: c.f32()?,
        k: c.u32()? as usize,
        t: c.u32()? as usize,
        seed: c.u64()?,
    };
    let cluster = ClusterConfig {
        bi_nodes: c.u32()? as usize,
        dp_nodes: c.u32()? as usize,
        cores_per_node: c.u32()? as usize,
        ag_copies: c.u32()? as usize,
        per_core_copies: c.u8()? != 0,
        replication: c.u32()? as usize,
        replica_route: replica_route_from_code(c.u8()?)?,
    };
    let stream = StreamConfig {
        obj_map: obj_map_from_code(c.u8()?)?,
        agg_bytes: c.u64()? as usize,
        dedup: c.u8()? != 0,
        max_candidates: c.u64()? as usize,
        inflight: c.u64()? as usize,
        // Session-side backpressure knob: never crosses the wire (workers
        // don't admit), and is deliberately excluded from the config
        // digest on both ends.
        pending_cap: 0,
    };
    c.done()?;
    Ok(Hello { node, epoch, dim, peers, lsh, cluster, stream, digest })
}

/// `HelloOk`: the responder echoes its slot and the config digest, plus
/// its *own* epoch — for a worker, the epoch of the shard it holds (0 if
/// empty; the file's stamp if it reloaded one via `--shard`). The driver
/// fences on the difference at rejoin.
pub fn encode_hello_ok(node: u16, digest: u64, epoch: u64) -> Vec<u8> {
    let mut p = Vec::with_capacity(18);
    put_u16(&mut p, node);
    put_u64(&mut p, digest);
    put_u64(&mut p, epoch);
    p
}

pub fn decode_hello_ok(payload: &[u8]) -> Result<(u16, u64, u64)> {
    let mut rd = Rd::new(payload);
    let node = rd.u16()?;
    let digest = rd.u64()?;
    let epoch = rd.u64()?;
    rd.done()?;
    Ok((node, digest, epoch))
}

pub fn encode_peer_hello(node: u16) -> Vec<u8> {
    let mut p = Vec::with_capacity(2);
    put_u16(&mut p, node);
    p
}

pub fn decode_peer_hello(payload: &[u8]) -> Result<u16> {
    let mut rd = Rd::new(payload);
    let node = rd.u16()?;
    rd.done()?;
    Ok(node)
}

// ------------------------------------------------------- cluster control

/// Bare epoch payload (`Pong`).
pub fn encode_epoch(epoch: u64) -> Vec<u8> {
    let mut p = Vec::with_capacity(8);
    put_u64(&mut p, epoch);
    p
}

pub fn decode_epoch(payload: &[u8]) -> Result<u64> {
    let mut rd = Rd::new(payload);
    let epoch = rd.u64()?;
    rd.done()?;
    Ok(epoch)
}

/// Bare slot-id payload (`RestoreOk`, `PersistOk`).
pub fn encode_slot_ack(slot: u16) -> Vec<u8> {
    let mut p = Vec::with_capacity(2);
    put_u16(&mut p, slot);
    p
}

pub fn decode_slot_ack(payload: &[u8]) -> Result<u16> {
    let mut rd = Rd::new(payload);
    let slot = rd.u16()?;
    rd.done()?;
    Ok(slot)
}

/// `Membership`: the session epoch plus, per worker slot, the live flag
/// and the slot's current listen address (rejoined workers get fresh
/// OS-assigned ports, so addresses must travel with liveness).
pub fn encode_membership(epoch: u64, slots: &[(bool, String)]) -> Vec<u8> {
    let mut p = Vec::with_capacity(10 + slots.len() * 16);
    put_u64(&mut p, epoch);
    put_u16(&mut p, slots.len() as u16);
    for (live, addr) in slots {
        put_u8(&mut p, *live as u8);
        put_str(&mut p, addr);
    }
    p
}

#[allow(clippy::type_complexity)]
pub fn decode_membership(payload: &[u8]) -> Result<(u64, Vec<(bool, String)>)> {
    let mut rd = Rd::new(payload);
    let epoch = rd.u64()?;
    let n = rd.u16()? as usize;
    let mut slots = Vec::with_capacity(n.min(rd.remaining() / 3));
    for _ in 0..n {
        let live = match rd.u8()? {
            0 => false,
            1 => true,
            b => bail!("bad liveness byte {b}"),
        };
        slots.push((live, rd.str()?));
    }
    rd.done()?;
    Ok((epoch, slots))
}

/// `Restore`: the epoch the shard is current at + an [`encode_state_dump`]
/// payload to replay. The dump rides opaquely so a driver can forward a
/// sibling's `StateDump` without re-encoding.
pub fn encode_restore(epoch: u64, dump: &[u8]) -> Vec<u8> {
    let mut p = Vec::with_capacity(8 + dump.len());
    put_u64(&mut p, epoch);
    p.extend_from_slice(dump);
    p
}

pub fn decode_restore(payload: &[u8]) -> Result<(u64, &[u8])> {
    let mut rd = Rd::new(payload);
    let epoch = rd.u64()?;
    let dump = rd.take(rd.remaining())?;
    Ok((epoch, dump))
}

/// `PersistReq`: checkpoint the shard at `path`, stamped with `epoch`.
pub fn encode_persist_req(epoch: u64, path: &str) -> Vec<u8> {
    let mut p = Vec::with_capacity(10 + path.len());
    put_u64(&mut p, epoch);
    put_str(&mut p, path);
    p
}

pub fn decode_persist_req(payload: &[u8]) -> Result<(u64, String)> {
    let mut rd = Rd::new(payload);
    let epoch = rd.u64()?;
    let path = rd.str()?;
    rd.done()?;
    Ok((epoch, path))
}

// --------------------------------------------------------------- control

pub fn encode_qid(qid: u32) -> Vec<u8> {
    let mut p = Vec::with_capacity(4);
    put_u32(&mut p, qid);
    p
}

pub fn decode_qid(payload: &[u8]) -> Result<u32> {
    let mut rd = Rd::new(payload);
    let qid = rd.u32()?;
    rd.done()?;
    Ok(qid)
}

pub fn encode_stopped(reason: &str) -> Vec<u8> {
    let mut p = Vec::new();
    put_str(&mut p, reason);
    p
}

pub fn decode_stopped(payload: &[u8]) -> Result<String> {
    let mut rd = Rd::new(payload);
    let reason = rd.str()?;
    rd.done()?;
    Ok(reason)
}

/// Front-door result frame payload (`FrameKind::Completion`): the finished
/// query in the *client's* qid namespace, the resolved [`QueryOptions`]
/// echo (same elision as `QueryVec`), the per-query pipeline seconds as an
/// exact f64 bit pattern, and the `(distance, id)` top-k. Distances travel
/// as f32 bit patterns, so an external client sees results bit-identical
/// to an in-process `IndexSession::recv_full`.
pub fn encode_completion(qid: u32, opts: &QueryOptions, secs: f64, hits: &[(f32, u32)]) -> Vec<u8> {
    let mut p = Vec::with_capacity(33 + 8 * hits.len());
    put_u32(&mut p, qid);
    put_opts(&mut p, opts);
    put_u64(&mut p, secs.to_bits());
    put_u32(&mut p, hits.len() as u32);
    for &(d, id) in hits {
        put_f32(&mut p, d);
        put_u32(&mut p, id);
    }
    p
}

#[allow(clippy::type_complexity)]
pub fn decode_completion(payload: &[u8]) -> Result<(u32, QueryOptions, f64, Vec<(f32, u32)>)> {
    let mut rd = Rd::new(payload);
    let qid = rd.u32()?;
    let opts = read_opts(&mut rd)?;
    let secs = f64::from_bits(rd.u64()?);
    let n = rd.len_prefix(8)?;
    let mut hits = Vec::with_capacity(n);
    for _ in 0..n {
        let d = rd.f32()?;
        let id = rd.u32()?;
        hits.push((d, id));
    }
    rd.done()?;
    Ok((qid, opts, secs, hits))
}

/// FlushAck: barrier sequence number + the worker's phase meter (per-link
/// real bytes-on-wire plus the logical/local/payload counters) + the phase
/// work counters of every stage copy this worker hosts, so the driver's
/// `SearchOutput::work` / `IndexSession::stats()` is complete under the
/// socket transport (not head-only).
pub fn encode_flush_ack(
    seq: u32,
    meter: &TrafficMeter,
    work: &[(StageKind, u16, WorkStats)],
) -> Vec<u8> {
    let mut p = Vec::new();
    put_u32(&mut p, seq);
    put_u64(&mut p, meter.logical_msgs);
    put_u64(&mut p, meter.local_msgs);
    put_u64(&mut p, meter.payload_bytes);
    let mut links: Vec<_> = meter.links().iter().map(|(&k, &v)| (k, v)).collect();
    links.sort_by_key(|(k, _)| *k);
    put_u32(&mut p, links.len() as u32);
    for ((src, dst), l) in links {
        put_u16(&mut p, src);
        put_u16(&mut p, dst);
        put_u64(&mut p, l.packets);
        put_u64(&mut p, l.bytes);
    }
    put_u32(&mut p, work.len() as u32);
    for (stage, copy, w) in work {
        put_u8(&mut p, stage.code());
        put_u16(&mut p, *copy);
        for v in [
            w.hash_vectors,
            w.probe_seqs,
            w.bucket_lookups,
            w.candidates_routed,
            w.dists_computed,
            w.dists_pruned,
            w.dup_skipped,
            w.bucket_skipped,
            w.objects_stored,
            w.reduce_pushes,
            w.bytes_resident,
        ] {
            put_u64(&mut p, v);
        }
    }
    p
}

#[allow(clippy::type_complexity)]
pub fn decode_flush_ack(
    payload: &[u8],
) -> Result<(u32, TrafficMeter, Vec<(StageKind, u16, WorkStats)>)> {
    let mut rd = Rd::new(payload);
    let seq = rd.u32()?;
    let mut meter = TrafficMeter::new(0);
    meter.header_bytes = 0;
    meter.logical_msgs = rd.u64()?;
    meter.local_msgs = rd.u64()?;
    meter.payload_bytes = rd.u64()?;
    let n = rd.len_prefix(20)?;
    for _ in 0..n {
        let src = rd.u16()?;
        let dst = rd.u16()?;
        let packets = rd.u64()?;
        let bytes = rd.u64()?;
        meter.add_link(src, dst, packets, bytes);
    }
    let n_work = rd.len_prefix(91)?; // 1 (stage) + 2 (copy) + 11 u64 counters
    let mut work = Vec::with_capacity(n_work);
    for _ in 0..n_work {
        let stage = StageKind::from_code(rd.u8()?)
            .ok_or_else(|| anyhow!("unknown stage code in work stats"))?;
        let copy = rd.u16()?;
        let w = WorkStats {
            hash_vectors: rd.u64()?,
            probe_seqs: rd.u64()?,
            bucket_lookups: rd.u64()?,
            candidates_routed: rd.u64()?,
            dists_computed: rd.u64()?,
            dists_pruned: rd.u64()?,
            dup_skipped: rd.u64()?,
            bucket_skipped: rd.u64()?,
            objects_stored: rd.u64()?,
            reduce_pushes: rd.u64()?,
            bytes_resident: rd.u64()?,
        };
        work.push((stage, copy, w));
    }
    rd.done()?;
    Ok((seq, meter, work))
}

// ------------------------------------------------------------- snapshots

/// One worker's stage state, decoded from a `StateDump` frame. Snapshots
/// preserve per-bucket insertion order, so the differential test can assert
/// state identity against an inline-built cluster down to that order.
#[derive(Debug, Default)]
pub struct NodeState {
    /// Per hosted BI copy: `(copy, [(bucket key, [(id, dp)])])`, key-sorted.
    pub bis: Vec<(u16, Vec<(u64, Vec<(u32, u16)>)>)>,
    /// Per hosted DP copy: `(copy, [(id, vector)])`, id-sorted.
    pub dps: Vec<(u16, Vec<(u32, Vec<f32>)>)>,
}

pub fn encode_state_dump(bis: &[BiState], dps: &[DpState]) -> Vec<u8> {
    let mut p = Vec::new();
    put_u32(&mut p, bis.len() as u32);
    for bi in bis {
        put_u16(&mut p, bi.copy);
        let snap = bi.buckets_snapshot();
        put_u32(&mut p, snap.len() as u32);
        for (key, refs) in snap {
            put_u64(&mut p, key);
            put_u32(&mut p, refs.len() as u32);
            for &(id, dp) in refs.iter() {
                put_u32(&mut p, id);
                put_u16(&mut p, dp);
            }
        }
    }
    put_u32(&mut p, dps.len() as u32);
    for dp in dps {
        put_u16(&mut p, dp.copy);
        let snap = dp.objects_snapshot();
        put_u32(&mut p, snap.len() as u32);
        for (id, v) in snap {
            put_u32(&mut p, id);
            put_f32s(&mut p, v);
        }
    }
    p
}

/// Re-encode a decoded [`NodeState`] into the exact `StateDump` payload
/// layout. The rejoin path needs this: the driver pulls a dump from a live
/// sibling replica (decoded by its reader thread) and forwards the bytes
/// inside a `Restore` frame to the rejoining worker.
pub fn encode_node_state(state: &NodeState) -> Vec<u8> {
    let mut p = Vec::new();
    put_u32(&mut p, state.bis.len() as u32);
    for (copy, buckets) in &state.bis {
        put_u16(&mut p, *copy);
        put_u32(&mut p, buckets.len() as u32);
        for (key, refs) in buckets {
            put_u64(&mut p, *key);
            put_u32(&mut p, refs.len() as u32);
            for &(id, dp) in refs {
                put_u32(&mut p, id);
                put_u16(&mut p, dp);
            }
        }
    }
    put_u32(&mut p, state.dps.len() as u32);
    for (copy, objs) in &state.dps {
        put_u16(&mut p, *copy);
        put_u32(&mut p, objs.len() as u32);
        for (id, v) in objs {
            put_u32(&mut p, *id);
            put_f32s(&mut p, v);
        }
    }
    p
}

pub fn decode_state_dump(payload: &[u8]) -> Result<NodeState> {
    let mut rd = Rd::new(payload);
    let mut out = NodeState::default();
    let n_bi = rd.len_prefix(2)?;
    for _ in 0..n_bi {
        let copy = rd.u16()?;
        let n_buckets = rd.len_prefix(12)?;
        let mut buckets = Vec::with_capacity(n_buckets);
        for _ in 0..n_buckets {
            let key = rd.u64()?;
            let n_refs = rd.len_prefix(6)?;
            let mut refs = Vec::with_capacity(n_refs);
            for _ in 0..n_refs {
                let id = rd.u32()?;
                let dp = rd.u16()?;
                refs.push((id, dp));
            }
            buckets.push((key, refs));
        }
        out.bis.push((copy, buckets));
    }
    let n_dp = rd.len_prefix(2)?;
    for _ in 0..n_dp {
        let copy = rd.u16()?;
        let n_objs = rd.len_prefix(8)?;
        let mut objs = Vec::with_capacity(n_objs);
        for _ in 0..n_objs {
            let id = rd.u32()?;
            let v = rd.f32s()?;
            objs.push((id, v));
        }
        out.dps.push((copy, objs));
    }
    rd.done()?;
    Ok(out)
}

// --------------------------------------------------- incremental decoding

/// Incremental frame reassembly for *nonblocking* readers (the poll-based
/// front door, `net::front`): push whatever bytes the socket yields, pull
/// complete frames out. [`read_frame`]'s validation order is preserved —
/// the header is checked the moment 12 bytes are buffered, so a hostile
/// length prefix is rejected with a typed [`WireError::Oversize`] *before*
/// any payload is buffered, and the checksum is verified when the payload
/// completes. Any error is terminal for the stream: framing is lost once a
/// byte is untrusted, so callers must drop the connection (matching the
/// blocking path, where the reader thread exits on the first bad frame).
#[derive(Debug, Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Prefix of `buf` already consumed by returned frames (compacted on
    /// the next `push`, so a burst of small frames costs one memmove).
    pos: usize,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Buffer more bytes from the socket.
    pub fn push(&mut self, bytes: &[u8]) {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet returned as a complete frame.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Pull the next complete frame, if the buffer holds one. `Ok(None)`
    /// means "need more bytes"; errors are typed and terminal.
    pub fn next_frame(&mut self, max_frame: usize) -> std::result::Result<Option<Frame>, WireError> {
        let b = &self.buf[self.pos..];
        if b.len() < HEADER_LEN {
            return Ok(None);
        }
        let magic = u16::from_le_bytes([b[0], b[1]]);
        if magic != MAGIC {
            return Err(WireError::BadMagic(magic));
        }
        if b[2] != WIRE_VERSION {
            return Err(WireError::VersionMismatch { got: b[2], want: WIRE_VERSION });
        }
        let kind = FrameKind::from_u8(b[3]).ok_or(WireError::UnknownKind(b[3]))?;
        let len = u32::from_le_bytes([b[4], b[5], b[6], b[7]]) as usize;
        if len > max_frame {
            return Err(WireError::Oversize { len, cap: max_frame });
        }
        if b.len() < HEADER_LEN + len {
            return Ok(None);
        }
        let crc = u32::from_le_bytes([b[8], b[9], b[10], b[11]]);
        let want = fnv1a32(fnv1a32(FNV_OFFSET, &b[0..8]), &b[HEADER_LEN..HEADER_LEN + len]);
        if crc != want {
            return Err(WireError::Checksum { got: crc, want });
        }
        let payload = b[HEADER_LEN..HEADER_LEN + len].to_vec();
        self.pos += HEADER_LEN + len;
        Ok(Some(Frame { kind, payload }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minitest::{check, Gen};

    fn read_back(frame: &[u8], max: usize) -> std::result::Result<Frame, WireError> {
        read_frame(&mut &frame[..], max)
    }

    fn rand_vec(g: &mut Gen, max_len: usize) -> Vec<f32> {
        let n = g.usize_in(0, max_len);
        g.vec_f32(n, -1e6, 1e6)
    }

    /// Random per-query options, zero (elided) fields included so the
    /// default-elision paths are exercised by every roundtrip run.
    fn rand_opts(g: &mut Gen) -> QueryOptions {
        QueryOptions {
            k: g.usize_in(0, 64) as u32,
            probes: g.usize_in(0, 512) as u32,
            tables: g.usize_in(0, 16) as u32,
            tag: g.usize_in(0, 1 << 20) as u32,
        }
    }

    fn rand_msg(g: &mut Gen) -> Msg {
        match g.usize_in(0, 8) {
            0 => Msg::IndexBlock {
                id_base: g.usize_in(0, 1 << 20) as u32,
                rows: g.usize_in(0, 64) as u32,
                flat: rand_vec(g, 256).into(),
            },
            1 => Msg::QueryVec {
                qid: g.usize_in(0, 1 << 20) as u32,
                raw: rand_vec(g, 64).into(),
                v: rand_vec(g, 128).into(),
                opts: rand_opts(g),
            },
            2 => Msg::StoreObject {
                id: g.usize_in(0, 1 << 20) as u32,
                v: rand_vec(g, 128).into(),
            },
            3 => Msg::IndexRef {
                table: g.usize_in(0, 255) as u8,
                key: g.rng.next_u64(),
                id: g.usize_in(0, 1 << 20) as u32,
                dp: g.usize_in(0, 1 << 12) as u16,
            },
            4 => Msg::Query {
                qid: g.usize_in(0, 1 << 20) as u32,
                probes: (0..g.usize_in(0, 40))
                    .map(|_| (g.usize_in(0, 255) as u8, g.rng.next_u64()))
                    .collect(),
                v: rand_vec(g, 128).into(),
                k: g.usize_in(1, 64) as u32,
            },
            5 => Msg::CandidateReq {
                qid: g.usize_in(0, 1 << 20) as u32,
                ids: (0..g.usize_in(0, 60))
                    .map(|_| g.usize_in(0, 1 << 20) as u32)
                    .collect(),
                v: rand_vec(g, 128).into(),
                k: g.usize_in(1, 64) as u32,
            },
            6 => Msg::QueryMeta {
                qid: g.usize_in(0, 1 << 20) as u32,
                n_bi: g.usize_in(0, 1 << 10) as u32,
                k: g.usize_in(1, 64) as u32,
            },
            7 => Msg::BiMeta {
                qid: g.usize_in(0, 1 << 20) as u32,
                n_dp: g.usize_in(0, 1 << 10) as u32,
            },
            _ => Msg::LocalTopK {
                qid: g.usize_in(0, 1 << 20) as u32,
                hits: (0..g.usize_in(0, 30))
                    .map(|_| (g.f32_in(0.0, 1e9), g.usize_in(0, 1 << 20) as u32))
                    .collect(),
            },
        }
    }

    fn rand_dest(g: &mut Gen) -> Dest {
        let stage = *g.pick(&[StageKind::Bi, StageKind::Dp, StageKind::Ag]);
        Dest { stage, copy: g.usize_in(0, 1 << 10) as u16 }
    }

    #[test]
    fn stage_roundtrip_every_variant() {
        check("wire-stage-roundtrip", 200, |g| {
            let dest = rand_dest(g);
            let msg = rand_msg(g);
            let frame = stage_frame(dest, &msg);
            let f = read_back(&frame, 1 << 24).expect("read");
            assert_eq!(f.kind, FrameKind::Stage);
            let (d2, m2) = decode_stage(&f.payload).expect("decode");
            assert_eq!(dest, d2);
            assert_eq!(format!("{msg:?}"), format!("{m2:?}"));
        });
    }

    #[test]
    fn empty_vector_payloads_roundtrip() {
        let cases = vec![
            Msg::IndexBlock { id_base: 0, rows: 0, flat: Vec::new().into() },
            Msg::Query { qid: 1, probes: Vec::new(), v: Vec::new().into(), k: 1 },
            Msg::CandidateReq { qid: 2, ids: Vec::new(), v: Vec::new().into(), k: 1 },
            Msg::LocalTopK { qid: 3, hits: Vec::new() },
        ];
        for msg in cases {
            let frame = stage_frame(Dest::ag(0), &msg);
            let f = read_back(&frame, 1 << 16).unwrap();
            let (_, m2) = decode_stage(&f.payload).unwrap();
            assert_eq!(format!("{msg:?}"), format!("{m2:?}"));
        }
    }

    #[test]
    fn max_size_frames_pass_and_oversize_is_rejected() {
        let n = 1000usize; // payload = 3 (dest) + 1 (tag) + 4 + 4 + 4 + 4n
        let msg = Msg::IndexBlock {
            id_base: 0,
            rows: n as u32,
            flat: vec![1.5f32; n].into(),
        };
        let frame = stage_frame(Dest::bi(0), &msg);
        let payload_len = frame.len() - HEADER_LEN;
        // exactly at the cap: accepted
        let f = read_back(&frame, payload_len).unwrap();
        assert_eq!(f.payload.len(), payload_len);
        // one byte below the cap: rejected before allocating
        let err = read_back(&frame, payload_len - 1).unwrap_err();
        assert!(err.to_string().contains("exceeds cap"), "{err}");
    }

    #[test]
    fn every_single_byte_corruption_is_rejected() {
        let msg = Msg::CandidateReq {
            qid: 7,
            ids: vec![1, 2, 3, 99],
            v: vec![0.5f32; 16].into(),
            k: 10,
        };
        let frame = stage_frame(Dest::dp(3), &msg);
        for i in 0..frame.len() {
            let mut bad = frame.clone();
            bad[i] ^= 0x40;
            let rejected = match read_back(&bad, 1 << 16) {
                Err(_) => true,
                // A flipped length byte can only slip past the cap check by
                // *shrinking* the frame; the checksum then has to catch it.
                Ok(f) => decode_stage(&f.payload).is_err(),
            };
            assert!(rejected, "flip at byte {i} went undetected");
        }
    }

    #[test]
    fn truncated_frames_error() {
        let frame = stage_frame(Dest::ag(1), &Msg::QueryMeta { qid: 5, n_bi: 2, k: 10 });
        for cut in [0, HEADER_LEN - 1, HEADER_LEN + 2, frame.len() - 1] {
            assert!(read_back(&frame[..cut], 1 << 16).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn query_options_roundtrip_with_default_elision() {
        // all-inherit options cost exactly the flags byte...
        let mut elided = Vec::new();
        put_opts(&mut elided, &QueryOptions::default());
        assert_eq!(elided, vec![0u8]);
        // ...partially-set options carry only the set fields...
        let mut partial = Vec::new();
        put_opts(&mut partial, &QueryOptions { probes: 7, ..Default::default() });
        assert_eq!(partial.len(), 5);
        // ...and every combination roundtrips exactly (zeros included)
        check("wire-opts-roundtrip", 200, |g| {
            let o = rand_opts(g);
            let mut b = Vec::new();
            put_opts(&mut b, &o);
            assert_eq!(b.len(), o.wire_size(), "encoding disagrees with the size model");
            let mut rd = Rd::new(&b);
            let o2 = read_opts(&mut rd).expect("decode");
            rd.done().expect("no trailing bytes");
            assert_eq!(o, o2);
        });
        // unknown flag bits are rejected, not ignored
        let bad_flags = [0x20u8];
        let mut rd = Rd::new(&bad_flags);
        assert!(read_opts(&mut rd).is_err());
    }

    #[test]
    fn v2_frames_are_rejected_with_a_typed_error() {
        // Craft a well-formed *v2* frame: same layout, version byte 2,
        // checksum valid for that header — exactly what a live v2 peer
        // would emit. It must surface as VersionMismatch, not a panic,
        // not a checksum/misparse error.
        let mut frame = stage_frame(Dest::ag(0), &Msg::BiMeta { qid: 1, n_dp: 2 });
        frame[2] = 2; // version byte
        let crc = fnv1a32(fnv1a32(FNV_OFFSET, &frame[0..8]), &frame[HEADER_LEN..]);
        frame[8..12].copy_from_slice(&crc.to_le_bytes());
        match read_back(&frame, 1 << 16) {
            Err(WireError::VersionMismatch { got: 2, want }) => {
                assert_eq!(want, WIRE_VERSION);
            }
            other => panic!("v2 frame not rejected as VersionMismatch: {other:?}"),
        }
        // the Display form names both versions for the operator
        let e = read_back(&frame, 1 << 16).unwrap_err();
        assert!(e.to_string().contains("wire version 2"), "{e}");
        // a v2 handshake config block fails the version check inside Hello
        // decoding too (the digest covers the version byte)
        let hello = Hello {
            node: 0,
            epoch: 0,
            dim: 16,
            peers: vec!["127.0.0.1:1".into()],
            lsh: LshParams { l: 2, m: 4, w: 4.0, k: 3, t: 2, seed: 1 },
            cluster: ClusterConfig {
                bi_nodes: 1,
                dp_nodes: 1,
                cores_per_node: 1,
                ag_copies: 1,
                per_core_copies: false,
                replication: 1,
                replica_route: ReplicaRoute::RoundRobin,
            },
            stream: StreamConfig::default(),
            digest: 0,
        };
        let mut p = encode_hello(&hello);
        // the cfg block starts after node(2) + epoch(8) + n_peers(2) + one
        // addr (2 + len) + cfg_len(4); its first byte is the version
        let addr_len = hello.peers[0].len();
        let ver_at = 2 + 8 + 2 + 2 + addr_len + 4;
        assert_eq!(p[ver_at], WIRE_VERSION);
        p[ver_at] = 2;
        // refresh the trailing digest so only the version disagrees
        let cfg_start = ver_at;
        let cfg_end = p.len() - 8;
        let digest = fnv1a64(FNV64_OFFSET, &p[cfg_start..cfg_end]);
        let at = p.len() - 8;
        p[at..].copy_from_slice(&digest.to_le_bytes());
        let err = decode_hello(&p).unwrap_err();
        assert!(err.to_string().contains("wire version 2"), "{err}");
    }

    #[test]
    fn hello_roundtrip_and_digest() {
        let hello = Hello {
            node: 2,
            epoch: 5,
            dim: 128,
            peers: vec!["127.0.0.1:41000".into(), "127.0.0.1:41001".into(), "127.0.0.1:41002".into()],
            lsh: LshParams { l: 4, m: 8, w: 600.0, k: 5, t: 8, seed: 3 },
            cluster: ClusterConfig {
                bi_nodes: 1,
                dp_nodes: 2,
                cores_per_node: 4,
                ag_copies: 2,
                per_core_copies: false,
                replication: 2,
                replica_route: ReplicaRoute::Layered,
            },
            stream: StreamConfig {
                obj_map: ObjMapStrategy::Lsh,
                agg_bytes: 4096,
                dedup: true,
                max_candidates: 7,
                inflight: 2,
                pending_cap: 0,
            },
            digest: 0,
        };
        let p = encode_hello(&hello);
        let h2 = decode_hello(&p).unwrap();
        assert_eq!(h2.node, 2);
        assert_eq!(h2.epoch, 5);
        assert_eq!(h2.dim, 128);
        assert_eq!(h2.peers, hello.peers);
        assert_eq!(h2.lsh, hello.lsh);
        assert_eq!(h2.cluster.dp_nodes, 2);
        assert_eq!(h2.cluster.replication, 2);
        assert_eq!(h2.cluster.replica_route, ReplicaRoute::Layered);
        assert_eq!(h2.stream.obj_map, ObjMapStrategy::Lsh);
        assert_eq!(h2.stream.inflight, 2);
        assert_eq!(
            h2.digest,
            config_digest(128, &hello.lsh, &hello.cluster, &hello.stream)
        );
        // tampering with the config block is caught by the digest
        let mut bad = p.clone();
        let idx = p.len() - 12; // inside the cfg block, before the digest
        bad[idx] ^= 1;
        assert!(decode_hello(&bad).is_err());
    }

    #[test]
    fn flush_ack_meter_roundtrip() {
        let mut m = TrafficMeter::new(0);
        m.header_bytes = 0;
        m.send(0, 3, 100);
        m.send(0, 3, 50);
        m.send(1, 3, 10);
        m.send(2, 2, 999); // local
        let work = vec![
            (
                StageKind::Bi,
                2u16,
                WorkStats {
                    bucket_lookups: 7,
                    candidates_routed: 19,
                    dup_skipped: 3,
                    bucket_skipped: 2,
                    bytes_resident: 4096,
                    ..Default::default()
                },
            ),
            (
                StageKind::Dp,
                5u16,
                WorkStats {
                    dists_computed: 123,
                    dists_pruned: 31,
                    objects_stored: 44,
                    bytes_resident: 1 << 33, // gauges are full u64s on the wire
                    ..Default::default()
                },
            ),
        ];
        let p = encode_flush_ack(42, &m, &work);
        let (seq, m2, w2) = decode_flush_ack(&p).unwrap();
        assert_eq!(seq, 42);
        assert_eq!(m2.logical_msgs, 3);
        assert_eq!(m2.local_msgs, 1);
        assert_eq!(m2.payload_bytes, 160);
        assert_eq!(m2.total_packets(), m.total_packets());
        assert_eq!(m2.total_bytes(), m.total_bytes());
        assert_eq!(m2.links()[&(0, 3)].bytes, m.links()[&(0, 3)].bytes);
        assert_eq!(w2, work, "per-copy work stats must roundtrip");
        // no work entries is also valid (e.g. a worker hosting only BIs
        // that saw no traffic still acks with its empty list)
        let p = encode_flush_ack(7, &m, &[]);
        let (_, _, w) = decode_flush_ack(&p).unwrap();
        assert!(w.is_empty());
    }

    #[test]
    fn state_dump_roundtrip() {
        let mut bi = BiState::new(4, 1, 0);
        bi.on_index_ref(100, 1, 0);
        bi.on_index_ref(100, 2, 1);
        bi.on_index_ref(7, 3, 0);
        let mut dp = DpState::new(9, 4, 1, true);
        dp.on_store(11, &[1.0, 2.0, 3.0, 4.0]);
        dp.on_store(10, &[5.0, 6.0, 7.0, 8.0]);
        let p = encode_state_dump(&[bi], &[dp]);
        let st = decode_state_dump(&p).unwrap();
        assert_eq!(st.bis.len(), 1);
        let (copy, buckets) = &st.bis[0];
        assert_eq!(*copy, 4);
        assert_eq!(
            buckets,
            &vec![(7u64, vec![(3u32, 0u16)]), (100, vec![(1, 0), (2, 1)])]
        );
        let (copy, objs) = &st.dps[0];
        assert_eq!(*copy, 9);
        assert_eq!(
            objs,
            &vec![(10u32, vec![5.0, 6.0, 7.0, 8.0]), (11, vec![1.0, 2.0, 3.0, 4.0])]
        );
    }

    #[test]
    fn control_payloads_roundtrip() {
        assert_eq!(decode_qid(&encode_qid(77)).unwrap(), 77);
        assert_eq!(decode_peer_hello(&encode_peer_hello(3)).unwrap(), 3);
        assert_eq!(
            decode_hello_ok(&encode_hello_ok(2, 0xDEAD_BEEF, 9)).unwrap(),
            (2, 0xDEAD_BEEF, 9)
        );
        assert_eq!(
            decode_stopped(&encode_stopped("worker dispatch panicked")).unwrap(),
            "worker dispatch panicked"
        );
        // trailing garbage is rejected
        let mut p = encode_qid(1);
        p.push(0);
        assert!(decode_qid(&p).is_err());
    }

    #[test]
    fn completion_roundtrip_exact() {
        // empty hit list and all-default options are valid
        let p = encode_completion(0, &QueryOptions::default(), 0.0, &[]);
        let (qid, opts, secs, hits) = decode_completion(&p).unwrap();
        assert_eq!((qid, opts, secs.to_bits(), hits.len()), (0, QueryOptions::default(), 0u64, 0));
        // every field roundtrips bit-exactly, elision included
        check("wire-completion-roundtrip", 200, |g| {
            let qid = g.usize_in(0, 1 << 30) as u32;
            let opts = rand_opts(g);
            let secs = g.f32_in(0.0, 1e3) as f64;
            let hits: Vec<(f32, u32)> = (0..g.usize_in(0, 40))
                .map(|_| (g.f32_in(0.0, 1e9), g.usize_in(0, 1 << 20) as u32))
                .collect();
            let p = encode_completion(qid, &opts, secs, &hits);
            let (q2, o2, s2, h2) = decode_completion(&p).unwrap();
            assert_eq!(qid, q2);
            assert_eq!(opts, o2);
            assert_eq!(secs.to_bits(), s2.to_bits());
            assert_eq!(hits, h2);
        });
        // trailing garbage is rejected
        let mut p = encode_completion(9, &QueryOptions { k: 5, ..Default::default() }, 1.5, &[(0.5, 3)]);
        p.push(0);
        assert!(decode_completion(&p).is_err());
    }

    #[test]
    fn frame_decoder_reassembles_across_every_split_boundary() {
        // three back-to-back frames, as a nonblocking read would see them
        let mut stream = Vec::new();
        stream.extend_from_slice(&stage_frame(Dest::dp(1), &Msg::BiMeta { qid: 1, n_dp: 2 }));
        stream.extend_from_slice(&encode_frame(
            FrameKind::Completion,
            &encode_completion(7, &QueryOptions { probes: 9, ..Default::default() }, 0.25, &[(1.0, 4), (2.0, 8)]),
        ));
        stream.extend_from_slice(&encode_frame(FrameKind::Shutdown, &[]));
        // split the byte stream at every boundary: both chunks pushed
        // separately must still yield exactly the three frames, in order
        for cut in 0..=stream.len() {
            let mut dec = FrameDecoder::new();
            dec.push(&stream[..cut]);
            let mut kinds = Vec::new();
            while let Some(f) = dec.next_frame(1 << 16).expect("clean stream") {
                kinds.push(f.kind);
            }
            dec.push(&stream[cut..]);
            while let Some(f) = dec.next_frame(1 << 16).expect("clean stream") {
                if f.kind == FrameKind::Completion {
                    let (qid, opts, _, hits) = decode_completion(&f.payload).unwrap();
                    assert_eq!((qid, opts.probes, hits.len()), (7, 9, 2));
                }
                kinds.push(f.kind);
            }
            assert_eq!(
                kinds,
                vec![FrameKind::Stage, FrameKind::Completion, FrameKind::Shutdown],
                "split at {cut}"
            );
            assert_eq!(dec.buffered(), 0, "split at {cut} left bytes behind");
        }
    }

    #[test]
    fn frame_decoder_rejects_every_single_byte_corruption() {
        // the blocking-path corruption sweep, replayed through the
        // nonblocking reassembly path byte by byte (worst-case reads)
        let frame = stage_frame(
            Dest::dp(3),
            &Msg::CandidateReq { qid: 7, ids: vec![1, 2, 3, 99], v: vec![0.5f32; 16].into(), k: 10 },
        );
        for i in 0..frame.len() {
            let mut bad = frame.clone();
            bad[i] ^= 0x40;
            let mut dec = FrameDecoder::new();
            let mut outcome = Ok(None);
            for &b in &bad {
                dec.push(&[b]);
                outcome = dec.next_frame(1 << 16);
                match &outcome {
                    Ok(None) => continue,
                    _ => break,
                }
            }
            let rejected = match outcome {
                Err(_) => true,
                // A shrunken length prefix can pass the cap; the checksum
                // or the payload decoder then has to catch it.
                Ok(Some(f)) => decode_stage(&f.payload).is_err(),
                Ok(None) => true, // grown length: frame never completes
            };
            assert!(rejected, "flip at byte {i} went undetected");
        }
    }

    #[test]
    fn frame_decoder_rejects_hostile_header_before_buffering() {
        // an oversized length prefix is rejected the moment the 12-byte
        // header is complete — no payload is ever buffered
        let mut hdr = Vec::new();
        put_u16(&mut hdr, MAGIC);
        put_u8(&mut hdr, WIRE_VERSION);
        put_u8(&mut hdr, FrameKind::Stage as u8);
        put_u32(&mut hdr, u32::MAX); // declared 4 GiB payload
        put_u32(&mut hdr, 0); // crc never reached
        let mut dec = FrameDecoder::new();
        dec.push(&hdr[..HEADER_LEN - 1]);
        assert!(matches!(dec.next_frame(1 << 16), Ok(None)));
        dec.push(&hdr[HEADER_LEN - 1..]);
        match dec.next_frame(1 << 16) {
            Err(WireError::Oversize { len, cap }) => {
                assert_eq!(len, u32::MAX as usize);
                assert_eq!(cap, 1 << 16);
            }
            other => panic!("hostile length prefix not rejected: {other:?}"),
        }
        // a v2 header is a typed VersionMismatch through the same path
        let mut v2 = stage_frame(Dest::ag(0), &Msg::BiMeta { qid: 1, n_dp: 2 });
        v2[2] = 2;
        let crc = fnv1a32(fnv1a32(FNV_OFFSET, &v2[0..8]), &v2[HEADER_LEN..]);
        v2[8..12].copy_from_slice(&crc.to_le_bytes());
        let mut dec = FrameDecoder::new();
        dec.push(&v2);
        assert!(matches!(
            dec.next_frame(1 << 16),
            Err(WireError::VersionMismatch { got: 2, .. })
        ));
        // and garbage magic likewise
        let mut dec = FrameDecoder::new();
        dec.push(b"GET / HTTP/1.1\r\n");
        assert!(matches!(dec.next_frame(1 << 16), Err(WireError::BadMagic(_))));
    }

    #[test]
    fn cluster_control_payloads_roundtrip() {
        assert_eq!(decode_epoch(&encode_epoch(u64::MAX)).unwrap(), u64::MAX);
        assert_eq!(decode_slot_ack(&encode_slot_ack(7)).unwrap(), 7);

        let (epoch, path) = decode_persist_req(&encode_persist_req(3, "/tmp/s/slot02.shard")).unwrap();
        assert_eq!((epoch, path.as_str()), (3, "/tmp/s/slot02.shard"));

        // Restore wraps a real state dump opaquely
        let mut bi = BiState::new(0, 1, 0);
        bi.on_index_ref(42, 1, 0);
        let dump = encode_state_dump(&[bi], &[]);
        let p = encode_restore(9, &dump);
        let (e, d) = decode_restore(&p).unwrap();
        assert_eq!(e, 9);
        let st = decode_state_dump(d).unwrap();
        assert_eq!(st.bis[0].1, vec![(42u64, vec![(1u32, 0u16)])]);
        // the rejoin path re-encodes the decoded dump bit-for-bit
        assert_eq!(encode_node_state(&st), dump);

        // empty dump (a worker hosting nothing) is valid too
        let (e, d) = decode_restore(&encode_restore(1, &[])).unwrap();
        assert_eq!((e, d.len()), (1, 0));

        // trailing garbage is rejected on the fixed-size payloads
        let mut p = encode_epoch(4);
        p.push(0);
        assert!(decode_epoch(&p).is_err());
        let mut p = encode_slot_ack(4);
        p.push(0);
        assert!(decode_slot_ack(&p).is_err());
    }

    #[test]
    fn membership_roundtrip_and_corruption() {
        let slots = vec![
            (true, "127.0.0.1:41000".to_string()),
            (false, "127.0.0.1:41001".to_string()),
            (true, "127.0.0.1:9".to_string()),
        ];
        let p = encode_membership(12, &slots);
        let (epoch, s2) = decode_membership(&p).unwrap();
        assert_eq!(epoch, 12);
        assert_eq!(s2, slots);

        // empty table roundtrips (a session with zero workers is degenerate
        // but the codec must not choke)
        let (e, s) = decode_membership(&encode_membership(0, &[])).unwrap();
        assert_eq!((e, s.len()), (0, 0));

        // every single-byte corruption of the full frame is rejected or
        // yields a failed decode — never a silent misparse into a
        // different live mask of the same length
        let frame = encode_frame(FrameKind::Membership, &p);
        for i in 0..frame.len() {
            let mut bad = frame.clone();
            bad[i] ^= 0x40;
            let rejected = match read_back(&bad, 1 << 16) {
                Err(_) => true,
                Ok(f) => decode_membership(&f.payload).is_err(),
            };
            assert!(rejected, "flip at byte {i} went undetected");
        }

        // a liveness byte that is neither 0 nor 1 is a typed decode error
        let mut raw = encode_membership(1, &[(true, "a".to_string())]);
        // epoch(8) + count(2) → liveness byte at offset 10
        raw[10] = 2;
        assert!(decode_membership(&raw).is_err());
    }

    #[test]
    fn hello_ok_carries_the_rejoin_epoch() {
        // random epochs and digests roundtrip exactly
        check("wire-hello-ok-roundtrip", 200, |g| {
            let node = g.usize_in(0, u16::MAX as usize) as u16;
            let digest = g.rng.next_u64();
            let epoch = g.rng.next_u64();
            let (n2, d2, e2) = decode_hello_ok(&encode_hello_ok(node, digest, epoch)).unwrap();
            assert_eq!((node, digest, epoch), (n2, d2, e2));
        });
        // a v4-sized (10-byte, epoch-less) HelloOk is rejected, not
        // misparsed — the epoch field is load-bearing for join fencing
        let legacy = &encode_hello_ok(1, 2, 3)[..10];
        assert!(decode_hello_ok(legacy).is_err());
    }
}
