//! Per-connection state machine of the front door (DESIGN.md §Front
//! door). Each accepted socket moves `Handshake → Streaming → Closing`;
//! the read side reassembles partial frames with [`FrameDecoder`]
//! (nonblocking reads deliver arbitrary byte slices), and the write side
//! buffers egress up to `front.egress_cap` so one slow client can never
//! stall the event loop — the loop queues bytes and moves on, and a
//! client that lets the bound overflow is evicted instead of blocking
//! everyone else.

use crate::dataflow::message::QueryOptions;
use crate::net::wire::{self, FrameDecoder, FrameKind};
use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::TcpStream;

/// Decoded queries a connection may hold while waiting for admission.
/// When the park queue is full the loop stops polling the socket for
/// reads, so backpressure propagates to the client's TCP send side
/// rather than growing server memory. One read burst can briefly exceed
/// the bound (frames already buffered must land somewhere); the excess
/// is at most one socket read of frames.
pub(crate) const PARK_CAP: usize = 64;

/// Where in its lifecycle a connection is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Phase {
    /// `Hello` queued; waiting for the client's digest echo (`HelloOk`).
    Handshake,
    /// Protocol-live: queries in, completions out.
    Streaming,
    /// A typed goodbye (`Stopped`) is queued; the connection closes once
    /// it flushes (or on the next write error). No reads, no admission.
    Closing,
}

/// What one nonblocking read drain produced.
pub(crate) enum ReadOutcome {
    /// Buffered whatever was available (possibly nothing but WouldBlock).
    Progress,
    /// Orderly EOF from the peer.
    Eof,
    /// Transport error (connection reset and friends).
    Err(io::Error),
}

pub(crate) struct Conn {
    pub(crate) stream: TcpStream,
    pub(crate) peer: String,
    pub(crate) phase: Phase,
    /// This connection's admission lane on the shared session.
    pub(crate) lane: u32,
    pub(crate) decoder: FrameDecoder,
    /// Decoded queries waiting for admission: (client qid, vector, plan).
    pub(crate) parked: VecDeque<(u32, Vec<f32>, QueryOptions)>,
    /// session ticket id → client qid: the per-connection ticket
    /// namespace. A client reusing a qid before claiming it simply
    /// orphans the older submission.
    pub(crate) pending: HashMap<u64, u32>,
    /// Completions delivered, for the serve-loop stats.
    pub(crate) completions_sent: u64,
    /// Outbound bytes the kernel has not yet accepted.
    egress: Vec<u8>,
    /// Prefix of `egress` already written.
    sent: usize,
}

impl Conn {
    pub(crate) fn new(stream: TcpStream, peer: String, lane: u32) -> Conn {
        Conn {
            stream,
            peer,
            phase: Phase::Handshake,
            lane,
            decoder: FrameDecoder::new(),
            parked: VecDeque::new(),
            pending: HashMap::new(),
            completions_sent: 0,
            egress: Vec::new(),
            sent: 0,
        }
    }

    /// Drain the nonblocking socket into the frame decoder.
    pub(crate) fn read_ready(&mut self) -> ReadOutcome {
        let mut buf = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut buf) {
                Ok(0) => return ReadOutcome::Eof,
                Ok(n) => self.decoder.push(&buf[..n]),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return ReadOutcome::Progress,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return ReadOutcome::Err(e),
            }
        }
    }

    /// Queue an outbound frame. `false` means the egress bound would be
    /// exceeded — the caller evicts the slow client rather than letting
    /// it hold server memory hostage.
    pub(crate) fn push_egress(&mut self, frame: &[u8], cap: usize) -> bool {
        if self.buffered_egress() + frame.len() > cap {
            return false;
        }
        if self.sent > 0 {
            self.egress.drain(..self.sent);
            self.sent = 0;
        }
        self.egress.extend_from_slice(frame);
        true
    }

    pub(crate) fn buffered_egress(&self) -> usize {
        self.egress.len() - self.sent
    }

    pub(crate) fn wants_write(&self) -> bool {
        self.buffered_egress() > 0
    }

    /// Whether the event loop should poll this conn for reads: always
    /// during the handshake; while streaming only if the park queue has
    /// room (admission backpressure becomes TCP backpressure); never
    /// once closing.
    pub(crate) fn wants_read(&self) -> bool {
        match self.phase {
            Phase::Handshake => true,
            Phase::Streaming => self.parked.len() < PARK_CAP,
            Phase::Closing => false,
        }
    }

    /// Nonblocking write drain. `Ok(true)` = egress fully flushed.
    pub(crate) fn write_ready(&mut self) -> io::Result<bool> {
        while self.sent < self.egress.len() {
            match self.stream.write(&self.egress[self.sent..]) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "peer stopped accepting bytes",
                    ))
                }
                Ok(n) => self.sent += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        self.egress.clear();
        self.sent = 0;
        Ok(true)
    }

    /// Queue a typed goodbye and enter `Closing`: no more reads or
    /// admission; the connection is dropped once the `Stopped` frame
    /// flushes. Any unread egress is replaced — the client that provoked
    /// the close forfeits its backlog, deliberately, so the goodbye can
    /// never itself be blocked by a full buffer.
    pub(crate) fn begin_close(&mut self, reason: &str) {
        let frame = wire::encode_frame(FrameKind::Stopped, &wire::encode_stopped(reason));
        self.egress.clear();
        self.sent = 0;
        self.egress.extend_from_slice(&frame);
        self.phase = Phase::Closing;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire;
    use std::net::TcpListener;

    /// A connected nonblocking server-side conn plus its blocking client
    /// end, over loopback.
    fn pair() -> (Conn, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, peer) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        (Conn::new(server, peer.to_string(), 1), client)
    }

    #[test]
    fn read_ready_reassembles_split_frames_and_reports_eof() {
        let (mut conn, mut client) = pair();
        let f1 = wire::encode_frame(FrameKind::Shutdown, &[]);
        let f2 = wire::encode_frame(FrameKind::Stopped, &wire::encode_stopped("bye"));
        let bytes: Vec<u8> = f1.iter().chain(f2.iter()).copied().collect();
        // dribble the two frames across an awkward split
        client.write_all(&bytes[..7]).unwrap();
        client.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(matches!(conn.read_ready(), ReadOutcome::Progress));
        assert!(conn.decoder.next_frame(1 << 16).unwrap().is_none());
        client.write_all(&bytes[7..]).unwrap();
        drop(client);
        // drain until EOF shows up (bytes may land in several reads)
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        loop {
            match conn.read_ready() {
                ReadOutcome::Eof => break,
                ReadOutcome::Progress => {
                    assert!(
                        std::time::Instant::now() < deadline,
                        "EOF never surfaced"
                    );
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                ReadOutcome::Err(e) => panic!("unexpected transport error: {e}"),
            }
        }
        let a = conn.decoder.next_frame(1 << 16).unwrap().unwrap();
        let b = conn.decoder.next_frame(1 << 16).unwrap().unwrap();
        assert_eq!(a.kind, FrameKind::Shutdown);
        assert_eq!(b.kind, FrameKind::Stopped);
        assert_eq!(wire::decode_stopped(&b.payload).unwrap(), "bye");
        assert!(conn.decoder.next_frame(1 << 16).unwrap().is_none());
    }

    #[test]
    fn egress_bound_refuses_overflow_and_goodbye_replaces_backlog() {
        let (mut conn, _client) = pair();
        let frame = wire::encode_frame(FrameKind::Shutdown, &[]);
        let cap = frame.len() * 2;
        assert!(conn.push_egress(&frame, cap));
        assert!(conn.push_egress(&frame, cap));
        // a third frame would exceed the bound: refused, buffer unchanged
        assert!(!conn.push_egress(&frame, cap));
        assert_eq!(conn.buffered_egress(), frame.len() * 2);
        // the typed goodbye replaces the backlog and flips the phase
        conn.begin_close("slow client");
        assert_eq!(conn.phase, Phase::Closing);
        assert!(!conn.wants_read());
        assert!(conn.wants_write());
        // flush lands exactly the Stopped frame on the wire
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while !conn.write_ready().unwrap() {
            assert!(std::time::Instant::now() < deadline, "goodbye never flushed");
        }
        let mut server_read = _client.try_clone().unwrap();
        let f = wire::read_frame(&mut server_read, 1 << 16).unwrap();
        assert_eq!(f.kind, FrameKind::Stopped);
        assert_eq!(wire::decode_stopped(&f.payload).unwrap(), "slow client");
    }

    #[test]
    fn park_queue_gates_read_interest() {
        let (mut conn, _client) = pair();
        conn.phase = Phase::Streaming;
        assert!(conn.wants_read());
        for i in 0..PARK_CAP {
            conn.parked
                .push_back((i as u32, vec![0.0; 4], QueryOptions::default()));
        }
        assert!(!conn.wants_read(), "full park queue must drop read interest");
        conn.parked.pop_front();
        assert!(conn.wants_read());
    }
}
