//! The reusable external client of the front door: one blocking TCP
//! connection speaking the wire protocol — `Hello`/`HelloOk` handshake
//! with the config-digest echo, pipelined `QueryVec` submissions, and
//! `Completion` claims carrying the resolved option echo. Used by
//! `parlsh query --connect`, the front integration tests, and
//! `parlsh experiment front`.
//!
//! Clients never hash: they ship raw vectors and the server projects
//! them against its own hash family (external processes cannot hold the
//! family, and must not need to). Submission is pipelined — submit any
//! number of queries before claiming; completions arrive in the server's
//! completion order, matched to submissions by the client-local qid.

use crate::dataflow::message::{Dest, Msg, QueryOptions, StageKind};
use crate::net::peer::connect_retry;
use crate::net::wire::{self, FrameKind, Hello, WireError};
use anyhow::{anyhow, bail, Result};
use std::io::Write;
use std::net::TcpStream;

/// One claimed completion.
#[derive(Clone, Debug)]
pub struct Completed {
    /// The client-local qid [`Client::submit`] returned.
    pub qid: u32,
    /// The resolved plan the query actually ran under (option echo).
    pub opts: QueryOptions,
    /// Global top-k `(sqdist, id)`, ascending.
    pub hits: Vec<(f32, u32)>,
    /// Server-side admission-to-completion seconds.
    pub secs: f64,
}

pub struct Client {
    stream: TcpStream,
    hello: Hello,
    max_frame: usize,
    next_qid: u32,
}

impl Client {
    /// Connect and handshake with sensible retry defaults (the server
    /// may still be building its index when the client starts).
    pub fn connect(addr: &str) -> Result<Client> {
        Client::connect_with(addr, 40, 25, 64 << 20)
    }

    /// Connect with explicit retry/backoff and frame-size bounds.
    pub fn connect_with(
        addr: &str,
        retries: usize,
        backoff_ms: u64,
        max_frame: usize,
    ) -> Result<Client> {
        let mut stream = connect_retry(addr, retries, backoff_ms)?;
        let f = wire::read_frame(&mut stream, max_frame)
            .map_err(|e| anyhow!("front handshake: {e}"))?;
        if f.kind != FrameKind::Hello {
            bail!("front server opened with {:?}, want Hello", f.kind);
        }
        // decode_hello verifies the codec version and the config digest
        let hello = wire::decode_hello(&f.payload)?;
        let ok = wire::encode_frame(
            FrameKind::HelloOk,
            &wire::encode_hello_ok(hello.node, hello.digest, hello.epoch),
        );
        stream.write_all(&ok)?;
        Ok(Client { stream, hello, max_frame, next_qid: 0 })
    }

    /// The server's index parameters, as announced in the handshake.
    pub fn hello(&self) -> &Hello {
        &self.hello
    }

    /// Dimensionality queries must have.
    pub fn dim(&self) -> usize {
        self.hello.dim as usize
    }

    /// Bound how long [`Client::recv`] blocks. Tests use this to turn a
    /// starved client into a typed failure instead of a hang; `None`
    /// restores indefinite blocking.
    pub fn set_read_timeout(&self, dur: Option<std::time::Duration>) -> std::io::Result<()> {
        self.stream.set_read_timeout(dur)
    }

    /// Submit one query under `opts` (zero fields inherit the server's
    /// config); returns the client-local qid the matching [`Completed`]
    /// will carry. Pipelined: submit as many as you like before claiming.
    pub fn submit(&mut self, q: &[f32], opts: QueryOptions) -> Result<u32> {
        if q.len() != self.hello.dim as usize {
            bail!(
                "query has {} values, index dim is {}",
                q.len(),
                self.hello.dim
            );
        }
        let qid = self.next_qid;
        self.next_qid = self.next_qid.wrapping_add(1);
        // `raw` (the hashed projections) stays empty: the server hashes
        // server-side against its own family.
        let msg = Msg::QueryVec {
            qid,
            raw: Vec::new().into(),
            v: q.into(),
            opts,
        };
        let frame = wire::stage_frame(Dest { stage: StageKind::Qr, copy: 0 }, &msg);
        self.stream.write_all(&frame)?;
        Ok(qid)
    }

    /// Claim the next completion (blocking). Typed failures: a `Stopped`
    /// frame surfaces the server's reason (eviction, shutdown) verbatim;
    /// a dead connection surfaces the underlying IO error.
    pub fn recv(&mut self) -> Result<Completed> {
        let f = wire::read_frame(&mut self.stream, self.max_frame)
            .map_err(|e| anyhow!("front recv: {e}"))?;
        match f.kind {
            FrameKind::Completion => {
                let (qid, opts, secs, hits) = wire::decode_completion(&f.payload)?;
                Ok(Completed { qid, opts, hits, secs })
            }
            FrameKind::Stopped => {
                let reason = wire::decode_stopped(&f.payload)?;
                bail!("front server stopped this connection: {reason}")
            }
            other => bail!("unexpected {other:?} frame from front server"),
        }
    }

    /// Ask the server to shut down cleanly — it finishes every client's
    /// in-flight queries, flushes, and sends each connection a typed
    /// goodbye before exiting. Returns once the goodbye (or EOF) arrives.
    pub fn shutdown_server(mut self) -> Result<()> {
        self.stream
            .write_all(&wire::encode_frame(FrameKind::Shutdown, &[]))?;
        loop {
            match wire::read_frame(&mut self.stream, self.max_frame) {
                // late completions for queries we never claimed
                Ok(f) if f.kind == FrameKind::Completion => continue,
                Ok(f) if f.kind == FrameKind::Stopped => return Ok(()),
                Ok(f) => bail!("unexpected {:?} frame during shutdown", f.kind),
                // EOF/reset: the server is gone, which is the point
                Err(WireError::Io { .. }) => return Ok(()),
                Err(e) => bail!("front shutdown: {e}"),
            }
        }
    }
}
