//! The poll-based serving front door (DESIGN.md §Front door, paper §V:
//! Web-scale serving): `parlsh serve --listen <addr>` runs this
//! readiness-driven event loop, multiplexing many external TCP clients
//! onto ONE resident [`IndexSession`]. The session may itself execute
//! inline, threaded, or over `--net` socket workers — a two-tier
//! topology where this loop is the query fan-in tier and the worker mesh
//! the compute tier.
//!
//! One thread, no thread-per-connection: sockets are nonblocking and a
//! `poll(2)` wrapper ([`poll::Poller`]) reports readiness each tick. Per
//! connection, [`conn::Conn`] runs the `Handshake → Streaming → Closing`
//! state machine with partial-frame reassembly on reads and a bounded
//! egress buffer on writes (`front.egress_cap`): a slow client's results
//! queue up to the bound and then the client is *evicted* with a typed
//! goodbye — it can never block the loop or other clients.
//!
//! Fairness: each connection gets an admission *lane* on the session
//! ([`IndexSession::open_lane`]), bounding it to its fair share of
//! `stream.pending_cap`, and the loop admits parked queries round-robin
//! across connections — no client starves while another streams at full
//! rate. With `[qos] tags` configured the session additionally gates
//! each submission on its tag's weighted-fair share (DESIGN.md §QoS
//! scheduler) — lanes bound *connections*, tags bound *tenants*, and a
//! flooding tag parks at its share even across many connections. The
//! per-tag SLO rows land in [`FrontStats::per_tag`] at shutdown.
//!
//! A disconnect mid-stream closes the lane: in-flight tickets are
//! orphaned (completed by the pipeline, discarded on arrival), the
//! window share returns to survivors immediately, and the eviction is
//! logged. Queries decoded but not yet admitted when a client vanishes
//! are dropped with it.
//!
//! Shutdown: any streaming client may send a `Shutdown` frame; the loop
//! stops reading and accepting, drains every admitted query, flushes all
//! results, sends each connection a typed `Stopped` goodbye, and returns
//! its counters — the clean-exit contract `parlsh query --shutdown` and
//! CI rely on.

pub mod client;
pub(crate) mod conn;
pub mod poll;

pub use client::{Client, Completed};

use crate::config::Config;
use crate::coordinator::session::IndexSession;
use crate::dataflow::message::{Msg, StageKind};
use crate::net::wire::{self, Frame, FrameKind, Hello};
use crate::qos::TagStats;
use anyhow::Result;
use conn::{Conn, Phase, ReadOutcome};
use std::collections::{HashMap, VecDeque};
use std::io::{self, Write};
use std::net::TcpListener;
use std::time::{Duration, Instant};

/// Poll timeout while nothing is in flight anywhere (fresh accepts and
/// first bytes only need coarse latency).
const IDLE_TICK_MS: i32 = 25;
/// Poll timeout while queries, egress, or a shutdown drain are pending.
const BUSY_TICK_MS: i32 = 1;

/// Counters the serve loop reports when it exits (tests and the CLI
/// assert on these).
#[derive(Clone, Debug, Default)]
pub struct FrontStats {
    pub accepted: u64,
    /// Accepts refused over `front.max_conns` (typed notice, then close).
    pub refused: u64,
    /// Queries admitted into the pipeline.
    pub queries: u64,
    /// Completions delivered to clients.
    pub completions: u64,
    /// Connections evicted: protocol violations, handshake mismatches,
    /// slow-client egress overflow, or disconnects with work in flight.
    pub evictions: u64,
    /// Per-tag-class SLO rows snapshotted from the session at shutdown
    /// (the catch-all `*` row alone when `[qos] tags` is unset) — see
    /// `SessionStats::per_tag`.
    pub per_tag: Vec<TagStats>,
}

/// What handling one decoded frame asks the loop to do.
enum FrameAction {
    Proceed,
    Shutdown,
    Evict(String),
}

/// Serve external clients on `listener` until one sends `Shutdown`.
///
/// `session` must be attached with a ranker (the submit paths assert
/// it), and `cfg`/`dim` must be the exact configuration the session's
/// cluster was built with — the handshake digest announced to clients is
/// computed from them.
pub fn serve(
    listener: TcpListener,
    session: &IndexSession<'_>,
    cfg: &Config,
    dim: usize,
) -> Result<FrontStats> {
    listener.set_nonblocking(true)?;
    let max_frame = cfg.sock.max_frame_bytes;
    let egress_cap = cfg.front.egress_cap;
    let max_conns = cfg.front.max_conns;
    let expected_digest =
        wire::config_digest(dim as u32, &cfg.lsh, &cfg.cluster, &cfg.stream);

    let mut poller = poll::Poller::new();
    // Registry keyed by admission lane (unique per connection for the
    // session's lifetime), plus the round-robin service order.
    let mut conns: HashMap<u32, Conn> = HashMap::new();
    let mut rr: VecDeque<u32> = VecDeque::new();
    let mut doomed: Vec<u32> = Vec::new();
    let mut stats = FrontStats::default();
    let mut stopping = false;

    loop {
        // -- register interests and wait for readiness
        poller.clear();
        let accepting = !stopping;
        if accepting {
            poller.register(poll::fd_of(&listener), true, false);
        }
        let mut reg: Vec<(u32, usize)> = Vec::with_capacity(conns.len());
        for (&lane, c) in conns.iter() {
            let want_r = !stopping && c.wants_read();
            let want_w = c.wants_write();
            if want_r || want_w {
                reg.push((lane, poller.register(poll::fd_of(&c.stream), want_r, want_w)));
            }
        }
        let busy = stopping
            || conns.values().any(|c| {
                !c.pending.is_empty()
                    || !c.parked.is_empty()
                    || c.wants_write()
                    || c.phase == Phase::Closing
            });
        poller.wait(if busy { BUSY_TICK_MS } else { IDLE_TICK_MS })?;

        // -- accept new connections
        if accepting {
            loop {
                match listener.accept() {
                    Ok((s, peer)) => {
                        if s.set_nonblocking(true).is_err() {
                            continue;
                        }
                        let _ = s.set_nodelay(true);
                        if conns.len() >= max_conns {
                            // refuse with a typed notice; the socket is
                            // fresh, so the small frame fits its buffer
                            let notice = wire::encode_frame(
                                FrameKind::Stopped,
                                &wire::encode_stopped("front server full (front.max_conns)"),
                            );
                            let mut s = s;
                            let _ = s.write_all(&notice);
                            stats.refused += 1;
                            continue;
                        }
                        let lane = session.open_lane();
                        let mut c = Conn::new(s, peer.to_string(), lane);
                        let hello = Hello {
                            node: lane as u16,
                            epoch: 0,
                            dim: dim as u32,
                            peers: Vec::new(),
                            lsh: cfg.lsh,
                            cluster: cfg.cluster,
                            stream: cfg.stream,
                            // encode_hello computes the real digest
                            digest: 0,
                        };
                        let greeting =
                            wire::encode_frame(FrameKind::Hello, &wire::encode_hello(&hello));
                        c.push_egress(&greeting, egress_cap);
                        stats.accepted += 1;
                        rr.push_back(lane);
                        conns.insert(lane, c);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => {
                        // transient accept failures (ECONNABORTED and
                        // friends) must not kill a server with live clients
                        eprintln!("front: accept failed: {e}");
                        break;
                    }
                }
            }
        }

        // -- per-connection IO
        for &(lane, slot) in &reg {
            let readable = poller.readable(slot);
            let writable = poller.writable(slot);
            if !readable && !writable {
                continue;
            }
            let c = conns.get_mut(&lane).expect("registered conn vanished");
            if writable && c.wants_write() {
                if let Err(e) = c.write_ready() {
                    if c.phase != Phase::Closing {
                        eprintln!("front: {}: write failed: {e}", c.peer);
                    }
                    doomed.push(lane);
                    continue;
                }
            }
            if c.phase == Phase::Closing {
                if !c.wants_write() {
                    // goodbye flushed; drop the socket
                    doomed.push(lane);
                }
                continue;
            }
            if !readable || stopping {
                continue;
            }
            match c.read_ready() {
                ReadOutcome::Progress => {}
                ReadOutcome::Eof | ReadOutcome::Err(_) => {
                    // peer gone — frames already decoded can't be
                    // answered anyway; tear down at end of tick
                    doomed.push(lane);
                    continue;
                }
            }
            loop {
                match c.decoder.next_frame(max_frame) {
                    Ok(Some(frame)) => {
                        match handle_frame(c, frame, expected_digest, dim) {
                            FrameAction::Proceed => {}
                            FrameAction::Shutdown => {
                                eprintln!("front: shutdown requested by {}", c.peer);
                                stopping = true;
                            }
                            FrameAction::Evict(reason) => {
                                evict(session, c, lane, &mut stats, &reason);
                                break;
                            }
                        }
                    }
                    Ok(None) => break,
                    Err(we) => {
                        // hostile/corrupt bytes: typed rejection for this
                        // connection only; everyone else keeps streaming
                        evict(session, c, lane, &mut stats, &we.to_string());
                        break;
                    }
                }
            }
        }

        // -- fair admission: rotate the registry, one parked query per
        // connection per pass, until a full pass admits nothing. The
        // session's per-lane share bound is the hard fairness guarantee;
        // the rotation adds service order on top of it.
        if !stopping {
            loop {
                let mut progress = false;
                for _ in 0..rr.len() {
                    let Some(lane) = rr.pop_front() else { break };
                    let Some(c) = conns.get_mut(&lane) else {
                        // dead connection: drop its lane from the rotation
                        continue;
                    };
                    rr.push_back(lane);
                    if c.phase != Phase::Streaming {
                        continue;
                    }
                    if let Some((qid, v, opts)) = c.parked.front() {
                        if let Some(t) = session.try_submit_lane(lane, v, *opts) {
                            c.pending.insert(t.0, *qid);
                            c.parked.pop_front();
                            stats.queries += 1;
                            progress = true;
                        }
                    }
                }
                if !progress {
                    break;
                }
            }
        }

        // -- claim completions and route them to their connections
        while let Some((lane, (ticket, opts, hits, secs))) = session.try_recv_lane() {
            let Some(c) = conns.get_mut(&lane) else { continue };
            if c.phase == Phase::Closing {
                continue; // goodbye pending; the result is undeliverable
            }
            let Some(qid) = c.pending.remove(&ticket.0) else {
                continue;
            };
            let frame = wire::encode_frame(
                FrameKind::Completion,
                &wire::encode_completion(qid, &opts, secs, &hits),
            );
            if c.push_egress(&frame, egress_cap) {
                c.completions_sent += 1;
                stats.completions += 1;
            } else {
                let reason = format!(
                    "egress buffer would exceed front.egress_cap={egress_cap} (slow client)"
                );
                evict(session, c, lane, &mut stats, &reason);
            }
        }

        // -- opportunistic flush: results queued this tick usually fit
        // the socket buffer, so try now instead of waiting a full tick
        for (&lane, c) in conns.iter_mut() {
            if c.wants_write() {
                if let Err(e) = c.write_ready() {
                    if c.phase != Phase::Closing {
                        eprintln!("front: {}: write failed: {e}", c.peer);
                    }
                    doomed.push(lane);
                }
            }
        }

        // -- tear down doomed connections
        for lane in doomed.drain(..) {
            let Some(c) = conns.remove(&lane) else { continue };
            // Closing conns were already evicted (lane closed, eviction
            // counted) — this is just the socket drop.
            let was_closing = c.phase == Phase::Closing;
            let orphans = session.close_lane(lane);
            if !was_closing && (orphans > 0 || !c.parked.is_empty()) {
                stats.evictions += 1;
                eprintln!(
                    "front: {} disconnected mid-stream: {orphans} in-flight orphaned, {} parked dropped",
                    c.peer,
                    c.parked.len()
                );
            }
        }

        // -- clean shutdown once every admitted query has drained
        if stopping {
            let undelivered: usize = conns.values().map(|c| c.pending.len()).sum();
            if undelivered == 0 && session.in_flight() == 0 {
                break;
            }
        }
    }

    // Final drain: flush queued results (bounded patience — a client
    // that stopped reading forfeits its tail), then the typed goodbye.
    let deadline = Instant::now() + Duration::from_secs(5);
    while conns.values().any(|c| c.wants_write()) && Instant::now() < deadline {
        poller.clear();
        let regs: Vec<(u32, usize)> = conns
            .iter()
            .filter(|(_, c)| c.wants_write())
            .map(|(&l, c)| (l, poller.register(poll::fd_of(&c.stream), false, true)))
            .collect();
        poller.wait(50)?;
        for (lane, slot) in regs {
            if poller.writable(slot) {
                let c = conns.get_mut(&lane).expect("conn vanished in drain");
                if c.write_ready().is_err() {
                    conns.remove(&lane);
                    session.close_lane(lane);
                }
            }
        }
    }
    for (&lane, c) in conns.iter_mut() {
        session.close_lane(lane);
        c.begin_close("front server shutdown");
        let _ = c.write_ready(); // best effort; the frame is small
    }
    stats.per_tag = session.stats().per_tag;
    Ok(stats)
}

/// Advance one connection's state machine by one decoded frame.
fn handle_frame(c: &mut Conn, frame: Frame, expected_digest: u64, dim: usize) -> FrameAction {
    match (c.phase, frame.kind) {
        (Phase::Handshake, FrameKind::HelloOk) => match wire::decode_hello_ok(&frame.payload) {
            Ok((node, digest, _epoch)) => {
                if node != c.lane as u16 || digest != expected_digest {
                    return FrameAction::Evict(format!(
                        "handshake digest mismatch (got {digest:#018x}, want {expected_digest:#018x})"
                    ));
                }
                c.phase = Phase::Streaming;
                FrameAction::Proceed
            }
            Err(e) => FrameAction::Evict(format!("bad HelloOk: {e}")),
        },
        (Phase::Handshake, kind) => {
            FrameAction::Evict(format!("expected HelloOk, got {kind:?}"))
        }
        (Phase::Streaming, FrameKind::Stage) => match wire::decode_stage(&frame.payload) {
            Ok((dest, Msg::QueryVec { qid, v, opts, .. })) if dest.stage == StageKind::Qr => {
                if v.len() != dim {
                    return FrameAction::Evict(format!(
                        "query has {} values, index dim is {dim}",
                        v.len()
                    ));
                }
                c.parked.push_back((qid, v.to_vec(), opts));
                FrameAction::Proceed
            }
            Ok(_) => FrameAction::Evict("stage frame is not a QueryVec for QR".to_string()),
            Err(e) => FrameAction::Evict(format!("bad stage frame: {e}")),
        },
        (Phase::Streaming, FrameKind::Shutdown) => FrameAction::Shutdown,
        (Phase::Streaming, kind) => FrameAction::Evict(format!("unexpected {kind:?} frame")),
        // Closing conns are never read; nothing to do if we get here.
        (Phase::Closing, _) => FrameAction::Proceed,
    }
}

/// Typed eviction: close the lane now — reclaiming the client's window
/// share and orphaning its in-flight tickets — queue the goodbye, log,
/// count.
fn evict(
    session: &IndexSession<'_>,
    c: &mut Conn,
    lane: u32,
    stats: &mut FrontStats,
    reason: &str,
) {
    let orphans = session.close_lane(lane);
    eprintln!(
        "front: evicting {} ({reason}; {orphans} in-flight orphaned)",
        c.peer
    );
    c.begin_close(reason);
    stats.evictions += 1;
}
