//! Readiness notification for the front door's nonblocking sockets.
//!
//! `poll(2)` through a direct FFI declaration: std already links the C
//! library on every unix target, so — like the vendored `anyhow` — this
//! adds no registry dependency. One flat fd array rebuilt per loop tick
//! is exactly poll(2)'s data model, and at front-door scale (at most
//! `front.max_conns` fds) the rebuild costs microseconds against a
//! millisecond tick. Non-unix targets fall back to a short-sleep busy
//! poll that reports everything ready and lets the nonblocking reads and
//! writes resolve actual readiness via `WouldBlock` — degenerate but
//! correct, and it keeps the crate compiling everywhere without a
//! feature flag.

use std::io;

#[cfg(unix)]
pub type Fd = std::os::unix::io::RawFd;
#[cfg(not(unix))]
pub type Fd = i32;

/// Extract the pollable handle from a socket. The non-unix busy-poll
/// fallback never inspects it.
#[cfg(unix)]
pub fn fd_of<T: std::os::unix::io::AsRawFd>(t: &T) -> Fd {
    t.as_raw_fd()
}
#[cfg(not(unix))]
pub fn fd_of<T>(_t: &T) -> Fd {
    0
}

#[cfg(unix)]
mod sys {
    /// `struct pollfd` — identical layout and flag values on the unix
    /// libcs we target (glibc, musl, macOS).
    #[repr(C)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }
    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;
    extern "C" {
        pub fn poll(
            fds: *mut PollFd,
            nfds: std::os::raw::c_ulong,
            timeout: std::os::raw::c_int,
        ) -> std::os::raw::c_int;
    }
}

/// One readiness set per event-loop tick: `clear`, `register` every fd of
/// interest, `wait`, then ask which slots are readable/writable. Slots
/// are positional (the index `register` returned), so callers pair
/// results with their connections without any map.
pub struct Poller {
    #[cfg(unix)]
    fds: Vec<sys::PollFd>,
    #[cfg(not(unix))]
    fds: Vec<(bool, bool)>,
}

impl Poller {
    pub fn new() -> Poller {
        Poller { fds: Vec::new() }
    }

    /// Drop all registrations (start of a tick). Keeps the allocation.
    pub fn clear(&mut self) {
        self.fds.clear();
    }

    /// Watch `fd` for the given interests; returns the slot to query
    /// after [`Poller::wait`].
    #[cfg(unix)]
    pub fn register(&mut self, fd: Fd, read: bool, write: bool) -> usize {
        let mut events = 0i16;
        if read {
            events |= sys::POLLIN;
        }
        if write {
            events |= sys::POLLOUT;
        }
        self.fds.push(sys::PollFd { fd, events, revents: 0 });
        self.fds.len() - 1
    }
    #[cfg(not(unix))]
    pub fn register(&mut self, _fd: Fd, read: bool, write: bool) -> usize {
        self.fds.push((read, write));
        self.fds.len() - 1
    }

    /// Block until a registered fd is ready or `timeout_ms` elapses.
    /// Returns how many slots have events (0 = timed out). `EINTR` is
    /// retried — a signal must not spuriously wake the serve loop's
    /// callers into thinking a timeout passed.
    #[cfg(unix)]
    pub fn wait(&mut self, timeout_ms: i32) -> io::Result<usize> {
        if self.fds.is_empty() {
            if timeout_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(timeout_ms as u64));
            }
            return Ok(0);
        }
        loop {
            let n = unsafe {
                sys::poll(
                    self.fds.as_mut_ptr(),
                    self.fds.len() as std::os::raw::c_ulong,
                    timeout_ms,
                )
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
    #[cfg(not(unix))]
    pub fn wait(&mut self, timeout_ms: i32) -> io::Result<usize> {
        // Busy-poll fallback: nap briefly, then report everything ready;
        // the nonblocking IO calls sort out the truth via WouldBlock.
        std::thread::sleep(std::time::Duration::from_millis(
            (timeout_ms.max(1) as u64).min(10),
        ));
        Ok(self.fds.len())
    }

    /// Slot has data to read — or an error/hangup the next read will
    /// surface, which callers must treat as readable to observe the EOF.
    #[cfg(unix)]
    pub fn readable(&self, slot: usize) -> bool {
        self.fds[slot].revents & (sys::POLLIN | sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0
    }
    #[cfg(not(unix))]
    pub fn readable(&self, slot: usize) -> bool {
        self.fds[slot].0
    }

    /// Slot can take more bytes — or has an error the write will surface.
    #[cfg(unix)]
    pub fn writable(&self, slot: usize) -> bool {
        self.fds[slot].revents & (sys::POLLOUT | sys::POLLERR | sys::POLLHUP | sys::POLLNVAL) != 0
    }
    #[cfg(not(unix))]
    pub fn writable(&self, slot: usize) -> bool {
        self.fds[slot].1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn poll_reports_accept_data_and_write_readiness() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut p = Poller::new();
        let mut client = TcpStream::connect(addr).unwrap();
        // the pending connection makes the listener poll readable
        let mut ok = false;
        for _ in 0..200 {
            p.clear();
            let s = p.register(fd_of(&listener), true, false);
            if p.wait(100).unwrap() > 0 && p.readable(s) {
                ok = true;
                break;
            }
        }
        assert!(ok, "pending accept never polled readable");
        let (server_side, _) = listener.accept().unwrap();
        client.write_all(b"x").unwrap();
        client.flush().unwrap();
        // a byte in flight makes the server side readable; an idle
        // socket with buffer room is writable
        let mut ok = false;
        for _ in 0..200 {
            p.clear();
            let s = p.register(fd_of(&server_side), true, true);
            if p.wait(100).unwrap() > 0 && p.readable(s) {
                assert!(p.writable(s), "idle socket not polled writable");
                ok = true;
                break;
            }
        }
        assert!(ok, "byte in flight never polled readable");
    }
}
