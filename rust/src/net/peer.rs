//! Per-peer connection management: packet aggregation over TCP.
//!
//! A [`PeerConn`] buffers encoded frames per destination exactly as the
//! in-process stream layer's `TrafficMeter` models packets: frames
//! accumulate until `stream.agg_bytes` is reached, then go out in one
//! `write_all` (one "packet" of the labeled-stream buffering policy). The
//! caller flushes on idle — before blocking on events — so closed-loop
//! admission can never deadlock on a buffered frame, and flushes
//! explicitly at phase barriers. With `agg_bytes == 0` every frame is
//! written through immediately (aggregation off, packet per message).
//!
//! Metering stays with the *caller*: the routing code charges its
//! `TrafficMeter` with the encoded frame length (real bytes-on-wire, not
//! the `wire_size` model) next to each `send`, using the same
//! `agg_bytes` so meter packets track write batches (control frames ride
//! the same buffer but are never metered, so the two can differ slightly).

use std::io::{self, Write};
use std::net::TcpStream;
use std::time::Duration;

/// A buffered, aggregating writer over one TCP connection.
pub struct PeerConn {
    stream: TcpStream,
    buf: Vec<u8>,
    agg_bytes: usize,
}

impl PeerConn {
    pub fn new(stream: TcpStream, agg_bytes: usize) -> PeerConn {
        PeerConn { stream, buf: Vec::with_capacity(agg_bytes), agg_bytes }
    }

    /// Queue one encoded frame; writes through when the aggregation buffer
    /// fills (or immediately when aggregation is off).
    pub fn send(&mut self, frame: &[u8]) -> io::Result<()> {
        if self.agg_bytes == 0 {
            return self.stream.write_all(frame);
        }
        self.buf.extend_from_slice(frame);
        if self.buf.len() >= self.agg_bytes {
            self.flush()?;
        }
        Ok(())
    }

    /// Write out any buffered frames (idle point or phase barrier).
    pub fn flush(&mut self) -> io::Result<()> {
        if !self.buf.is_empty() {
            self.stream.write_all(&self.buf)?;
            self.buf.clear();
        }
        Ok(())
    }

    /// Flush pending frames, then write `frame` immediately — for control
    /// frames whose ordering after all queued messages matters (handshake,
    /// barriers, snapshots, shutdown).
    pub fn send_now(&mut self, frame: &[u8]) -> io::Result<()> {
        self.flush()?;
        self.stream.write_all(frame)
    }
}

/// Connect with bounded retries — workers bind asynchronously and peers
/// dial each other lazily, so the first attempt can race the listener.
pub fn connect_retry(addr: &str, retries: usize, backoff_ms: u64) -> io::Result<TcpStream> {
    let mut last = None;
    for attempt in 0..retries.max(1) {
        match TcpStream::connect(addr) {
            Ok(s) => {
                // We aggregate ourselves; Nagle would only add latency on
                // the closed-loop request/response pattern.
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) => last = Some(e),
        }
        if attempt + 1 < retries.max(1) {
            std::thread::sleep(Duration::from_millis(backoff_ms));
        }
    }
    Err(last.unwrap_or_else(|| io::Error::new(io::ErrorKind::Other, "no attempts")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire::{self, FrameKind};
    use std::io::Read;
    use std::net::TcpListener;

    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let tx = TcpStream::connect(addr).unwrap();
        let (rx, _) = listener.accept().unwrap();
        (tx, rx)
    }

    #[test]
    fn aggregation_defers_until_flush() {
        let (tx, mut rx) = pair();
        let mut pc = PeerConn::new(tx, 1 << 20);
        let frame = wire::encode_frame(FrameKind::Done, &wire::encode_qid(1));
        pc.send(&frame).unwrap();
        pc.send(&frame).unwrap();
        // nothing on the wire yet: both frames sit in the buffer
        rx.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let mut probe = [0u8; 1];
        assert!(rx.read(&mut probe).is_err(), "frame leaked before flush");
        pc.flush().unwrap();
        rx.set_read_timeout(None).unwrap();
        for _ in 0..2 {
            let f = wire::read_frame(&mut rx, 1 << 16).unwrap();
            assert_eq!(f.kind, FrameKind::Done);
            assert_eq!(wire::decode_qid(&f.payload).unwrap(), 1);
        }
    }

    #[test]
    fn send_now_preserves_frame_order() {
        let (tx, mut rx) = pair();
        let mut pc = PeerConn::new(tx, 1 << 20);
        pc.send(&wire::encode_frame(FrameKind::Done, &wire::encode_qid(7)))
            .unwrap();
        pc.send_now(&wire::encode_frame(FrameKind::FlushReq, &wire::encode_qid(8)))
            .unwrap();
        let f1 = wire::read_frame(&mut rx, 1 << 16).unwrap();
        assert_eq!(f1.kind, FrameKind::Done);
        let f2 = wire::read_frame(&mut rx, 1 << 16).unwrap();
        assert_eq!(f2.kind, FrameKind::FlushReq);
    }

    #[test]
    fn no_aggregation_writes_through() {
        let (tx, mut rx) = pair();
        let mut pc = PeerConn::new(tx, 0);
        pc.send(&wire::encode_frame(FrameKind::Done, &wire::encode_qid(5)))
            .unwrap();
        let f = wire::read_frame(&mut rx, 1 << 16).unwrap();
        assert_eq!(wire::decode_qid(&f.payload).unwrap(), 5);
    }

    #[test]
    fn connect_retry_reports_failure() {
        // a port nothing listens on (bind then drop to reserve-and-free)
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        assert!(connect_retry(&addr, 2, 1).is_err());
    }
}
