//! Per-peer connection management: packet aggregation over TCP.
//!
//! A [`PeerConn`] buffers encoded frames per destination exactly as the
//! in-process stream layer's `TrafficMeter` models packets: frames
//! accumulate until `stream.agg_bytes` is reached, and a frame that would
//! *overflow* the buffer flushes the buffered packet first — so no write
//! batch ever exceeds the aggregation budget unless a single frame does
//! (the meter's packet rule, asserted against a counting writer in the
//! tests below). The caller flushes on idle — before blocking on events —
//! so closed-loop admission can never deadlock on a buffered frame, and
//! flushes explicitly at phase barriers. With `agg_bytes == 0` every
//! frame is written through immediately (aggregation off, packet per
//! message).
//!
//! Metering stays with the *caller*: the routing code charges its
//! `TrafficMeter` with the encoded frame length (real bytes-on-wire, not
//! the `wire_size` model) next to each `send`, using the same
//! `agg_bytes` so meter packets track write batches (control frames ride
//! the same buffer but are never metered, so the two can differ slightly).

use std::io::{self, Write};
use std::net::TcpStream;
use std::time::Duration;

/// A buffered, aggregating writer over one connection. Generic over the
/// writer so tests can observe write batches deterministically; the wire
/// paths all use the `TcpStream` default.
pub struct PeerConn<W: Write = TcpStream> {
    stream: W,
    buf: Vec<u8>,
    agg_bytes: usize,
}

impl<W: Write> PeerConn<W> {
    pub fn new(stream: W, agg_bytes: usize) -> PeerConn<W> {
        PeerConn { stream, buf: Vec::with_capacity(agg_bytes), agg_bytes }
    }

    /// Queue one encoded frame; writes through when the aggregation buffer
    /// fills (or immediately when aggregation is off). A frame that would
    /// push the buffer past `agg_bytes` flushes the buffered batch first,
    /// mirroring the `TrafficMeter` packet model.
    pub fn send(&mut self, frame: &[u8]) -> io::Result<()> {
        if self.agg_bytes == 0 {
            return self.stream.write_all(frame);
        }
        if !self.buf.is_empty() && self.buf.len() + frame.len() > self.agg_bytes {
            self.flush()?;
        }
        self.buf.extend_from_slice(frame);
        if self.buf.len() >= self.agg_bytes {
            self.flush()?;
        }
        Ok(())
    }

    /// Bytes currently sitting in the aggregation buffer (not yet on the
    /// wire) — the deterministic seam the aggregation tests probe instead
    /// of racing a read timeout against the flush path.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Write out any buffered frames (idle point or phase barrier).
    pub fn flush(&mut self) -> io::Result<()> {
        if !self.buf.is_empty() {
            self.stream.write_all(&self.buf)?;
            self.buf.clear();
        }
        Ok(())
    }

    /// Flush pending frames, then write `frame` immediately — for control
    /// frames whose ordering after all queued messages matters (handshake,
    /// barriers, snapshots, shutdown).
    pub fn send_now(&mut self, frame: &[u8]) -> io::Result<()> {
        self.flush()?;
        self.stream.write_all(frame)
    }
}

/// Connect with bounded retries — workers bind asynchronously and peers
/// dial each other lazily, so the first attempt can race the listener.
pub fn connect_retry(addr: &str, retries: usize, backoff_ms: u64) -> io::Result<TcpStream> {
    let mut last = None;
    for attempt in 0..retries.max(1) {
        match TcpStream::connect(addr) {
            Ok(s) => {
                // We aggregate ourselves; Nagle would only add latency on
                // the closed-loop request/response pattern.
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) => last = Some(e),
        }
        if attempt + 1 < retries.max(1) {
            std::thread::sleep(Duration::from_millis(backoff_ms));
        }
    }
    Err(last.unwrap_or_else(|| io::Error::new(io::ErrorKind::Other, "no attempts")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::metrics::TrafficMeter;
    use crate::net::wire::{self, FrameKind};
    use std::net::TcpListener;

    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let tx = TcpStream::connect(addr).unwrap();
        let (rx, _) = listener.accept().unwrap();
        (tx, rx)
    }

    /// Records every `write_all` batch — the seam for asserting the
    /// aggregation policy without reading real sockets on a timeout.
    struct CountingWriter {
        batches: Vec<usize>,
    }

    impl Write for CountingWriter {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.batches.push(buf.len());
            Ok(buf.len())
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn aggregation_defers_until_flush() {
        let (tx, mut rx) = pair();
        let mut pc = PeerConn::new(tx, 1 << 20);
        let frame = wire::encode_frame(FrameKind::Done, &wire::encode_qid(1));
        pc.send(&frame).unwrap();
        pc.send(&frame).unwrap();
        // both frames sit in the aggregation buffer, nothing on the wire —
        // asserted on the buffer itself, not with a read-timeout probe
        assert_eq!(pc.buffered(), 2 * frame.len());
        pc.flush().unwrap();
        assert_eq!(pc.buffered(), 0);
        for _ in 0..2 {
            let f = wire::read_frame(&mut rx, 1 << 16).unwrap();
            assert_eq!(f.kind, FrameKind::Done);
            assert_eq!(wire::decode_qid(&f.payload).unwrap(), 1);
        }
    }

    #[test]
    fn overflowing_frame_flushes_the_buffered_packet_first() {
        let mut pc = PeerConn::new(CountingWriter { batches: Vec::new() }, 100);
        pc.send(&[7u8; 80]).unwrap();
        assert_eq!(pc.buffered(), 80);
        // 80 + 60 would exceed the 100-byte budget: the 80 go out alone
        pc.send(&[7u8; 60]).unwrap();
        assert_eq!(pc.buffered(), 60);
        // a single oversized frame is one oversized write, by itself
        pc.send(&[7u8; 300]).unwrap();
        assert_eq!(pc.buffered(), 0);
        pc.flush().unwrap();
        assert_eq!(pc.stream.batches, vec![80, 60, 300]);
    }

    #[test]
    fn write_batches_agree_with_the_meter_packet_model() {
        // The same frame sequence through a PeerConn and a TrafficMeter
        // (header_bytes = 0, as the wire paths configure it) must produce
        // identical packet boundaries.
        let sizes = [40usize, 90, 10, 10, 200, 5, 96, 4, 1];
        let agg = 100usize;
        let mut pc = PeerConn::new(CountingWriter { batches: Vec::new() }, agg);
        let mut meter = TrafficMeter::new(agg);
        meter.header_bytes = 0;
        for &s in &sizes {
            pc.send(&vec![0u8; s]).unwrap();
            meter.send(0, 1, s);
        }
        pc.flush().unwrap();
        meter.flush();
        assert_eq!(
            pc.stream.batches.iter().sum::<usize>(),
            sizes.iter().sum::<usize>()
        );
        assert_eq!(pc.stream.batches.len() as u64, meter.total_packets());
        assert_eq!(
            pc.stream.batches.iter().sum::<usize>() as u64,
            meter.total_bytes()
        );
        // no batch exceeds the budget unless a single frame did (the 200)
        for &b in &pc.stream.batches {
            assert!(b <= agg || b == 200, "batch of {b} overflowed the budget");
        }
    }

    #[test]
    fn send_now_preserves_frame_order() {
        let (tx, mut rx) = pair();
        let mut pc = PeerConn::new(tx, 1 << 20);
        pc.send(&wire::encode_frame(FrameKind::Done, &wire::encode_qid(7)))
            .unwrap();
        pc.send_now(&wire::encode_frame(FrameKind::FlushReq, &wire::encode_qid(8)))
            .unwrap();
        let f1 = wire::read_frame(&mut rx, 1 << 16).unwrap();
        assert_eq!(f1.kind, FrameKind::Done);
        let f2 = wire::read_frame(&mut rx, 1 << 16).unwrap();
        assert_eq!(f2.kind, FrameKind::FlushReq);
    }

    #[test]
    fn no_aggregation_writes_through() {
        let (tx, mut rx) = pair();
        let mut pc = PeerConn::new(tx, 0);
        pc.send(&wire::encode_frame(FrameKind::Done, &wire::encode_qid(5)))
            .unwrap();
        let f = wire::read_frame(&mut rx, 1 << 16).unwrap();
        assert_eq!(wire::decode_qid(&f.payload).unwrap(), 5);
    }

    #[test]
    fn connect_retry_reports_failure() {
        // a port nothing listens on (bind then drop to reserve-and-free)
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        assert!(connect_retry(&addr, 2, 1).is_err());
    }
}
