//! Vendored minimal stand-in for the `anyhow` crate.
//!
//! This environment has no crate-registry access, so the exact `anyhow`
//! surface the workspace uses — [`Error`], [`Result`], [`anyhow!`],
//! [`bail!`], [`Context`] — is implemented here as a path dependency.
//! Errors are a flattened message chain (context prepends, `:`-separated),
//! which matches how the callers format them (`{e}` / `{e:#}`).

use std::fmt;

/// A flattened error: the message chain, outermost context first.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend context, mirroring `anyhow::Error::context`.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like real `anyhow`, this relies on `Error` NOT implementing
// `std::error::Error`, which keeps the blanket impl coherent with the
// std identity `impl From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` (any displayable error type).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(&$err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_err()?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("boom"));
    }

    #[test]
    fn context_prepends() {
        let e = io_err().with_context(|| "reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: boom");
        let e2 = io_err().context("open").unwrap_err();
        assert_eq!(e2.to_string(), "open: boom");
    }

    #[test]
    fn macros_format() {
        let name = "x";
        let e = anyhow!("missing {name} at {}", 7);
        assert_eq!(e.to_string(), "missing x at 7");
        let e = anyhow!("plain {name}");
        assert_eq!(e.to_string(), "plain x");
        let s = String::from("from-expr");
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "from-expr");
        fn bails() -> Result<()> {
            bail!("nope {}", 1)
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope 1");
    }
}
