"""Kernel-vs-reference correctness: the core L1 signal.

hypothesis sweeps shapes; fixed-seed numpy supplies the data. Tolerances:
the kernels accumulate in f32 like the references, so allclose is tight for
distances; hash outputs are integers and must match *exactly* except at
quantization-boundary ties, which we exclude by construction (see
``_safe_offsets``).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hash_batch, sqdist
from compile.kernels.ref import hash_batch_ref, rank_ref, sqdist_ref


def _rng(seed):
    return np.random.default_rng(seed)


def _vectors(rng, n, d, scale=1.0):
    return (rng.standard_normal((n, d)) * scale).astype(np.float32)


# ---------------------------------------------------------------- lsh_hash


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 300),
    d=st.sampled_from([4, 32, 128]),
    p=st.sampled_from([8, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hash_matches_ref(b, d, p, seed):
    rng = _rng(seed)
    x = _vectors(rng, b, d)
    a = _vectors(rng, d, p)
    w = 4.0
    off = rng.uniform(0, w, size=p).astype(np.float32)
    got = np.asarray(hash_batch(x, a, off, 1.0 / w))
    want = np.asarray(hash_batch_ref(x, a, off, 1.0 / w))
    # floor() may legitimately differ by 1 when the projection lands within
    # f32 rounding of a bucket boundary; require <0.1% such ties.
    diff = got != want
    assert diff.mean() < 1e-3, f"{diff.sum()} mismatches of {diff.size}"


def test_hash_exact_on_aligned_batch():
    rng = _rng(7)
    x = _vectors(rng, 256, 128)
    a = _vectors(rng, 128, 256)
    off = rng.uniform(0, 4.0, size=256).astype(np.float32)
    got = np.asarray(hash_batch(x, a, off, 0.25))
    want = np.asarray(hash_batch_ref(x, a, off, 0.25))
    assert (got != want).mean() < 1e-3


def test_hash_is_translation_covariant():
    # h(v) = floor((a.v + b)/w): shifting v by w * a / ||a||^2 along a single
    # projection direction shifts that coordinate by exactly 1.
    rng = _rng(3)
    d = 16
    a = _vectors(rng, d, 1)
    x = _vectors(rng, 8, d)
    w = 2.0
    off = np.zeros(1, np.float32)
    shifted = x + (w * a / (a * a).sum()).T
    h0 = np.asarray(hash_batch(x, a, off, 1.0 / w))
    h1 = np.asarray(hash_batch(shifted, a, off, 1.0 / w))
    assert np.abs((h1 - h0) - 1).max() <= 1  # exact 1 except boundary ties


def test_hash_batch_of_one():
    rng = _rng(11)
    x = _vectors(rng, 1, 128)
    a = _vectors(rng, 128, 256)
    off = rng.uniform(0, 4.0, size=256).astype(np.float32)
    got = np.asarray(hash_batch(x, a, off, 0.25))
    assert got.shape == (1, 256)


# ------------------------------------------------------------- l2_distance


@settings(max_examples=25, deadline=None)
@given(
    bq=st.integers(1, 17),
    n=st.integers(1, 1200),
    d=st.sampled_from([4, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sqdist_matches_ref(bq, n, d, seed):
    rng = _rng(seed)
    q = _vectors(rng, bq, d)
    c = _vectors(rng, n, d)
    got = np.asarray(sqdist(q, c))
    want = np.asarray(sqdist_ref(q, c))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_sqdist_zero_diagonal():
    rng = _rng(5)
    v = _vectors(rng, 64, 128)
    d = np.asarray(sqdist(v, v))
    assert np.abs(np.diag(d)).max() < 1e-2
    assert (d + 1e-2 >= 0).all()


def test_sqdist_sift_scale():
    # SIFT-like magnitudes (0..255) stress f32 cancellation in the
    # ||q||^2+||c||^2-2qc form; tolerance is relative to the ~1e6 scale.
    rng = _rng(9)
    q = rng.uniform(0, 255, (4, 128)).astype(np.float32)
    c = rng.uniform(0, 255, (700, 128)).astype(np.float32)
    got = np.asarray(sqdist(q, c))
    want = np.asarray(sqdist_ref(q, c))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1.0)


# ------------------------------------------------------------------ model


def test_rank_graph_matches_ref():
    from compile import model

    rng = _rng(13)
    q = _vectors(rng, 2, 128)
    c = _vectors(rng, 256, 128)
    n_valid = np.array([[200]], np.int32)
    k = 10
    dists, idx = model.rank_graph(q, c, n_valid, k)
    rvals, ridx = rank_ref(q, c, 200, k)
    np.testing.assert_allclose(np.asarray(dists), np.asarray(rvals), rtol=1e-4, atol=1e-3)
    # indices may differ on exact ties; compare by distance values instead.
    assert np.asarray(idx).max() < 200


def test_rank_graph_respects_n_valid():
    from compile import model

    rng = _rng(17)
    q = _vectors(rng, 1, 128)
    c = np.zeros((64, 128), np.float32)  # padding rows are all-zero = near q?
    c[:4] = _vectors(rng, 4, 128) + 10.0  # only 4 valid, far away
    n_valid = np.array([[4]], np.int32)
    dists, idx = model.rank_graph(q, c, n_valid, 10)
    idx = np.asarray(idx)
    dists = np.asarray(dists)
    assert (idx[0, :4] < 4).all()
    assert np.isinf(dists[0, 4:]).all()  # only 4 valid candidates exist


def test_rank_graph_n_valid_zero():
    from compile import model

    rng = _rng(19)
    q = _vectors(rng, 1, 128)
    c = _vectors(rng, 32, 128)
    dists, _ = model.rank_graph(q, c, np.array([[0]], np.int32), 10)
    assert np.isinf(np.asarray(dists)).all()
