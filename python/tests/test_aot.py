"""Lowering smoke tests: every artifact variant lowers to parseable HLO text."""

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_hash_lowering_smoke():
    lowered = aot.lower_hash(64)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_rank_lowering_smoke():
    lowered = aot.lower_rank(1, 256)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # top-k is implemented via lax.sort so it lowers to plain `sort` HLO
    # (the `topk` instruction is unparseable by xla_extension 0.5.1).
    assert "sort" in text
    assert "topk" not in text


def test_lowered_hash_executes_like_eager():
    # compile the lowered module and compare against the eager graph
    lowered = aot.lower_hash(64)
    compiled = lowered.compile()
    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, aot.D)).astype(np.float32)
    a = rng.standard_normal((aot.D, aot.P)).astype(np.float32)
    b = rng.uniform(0, 4.0, aot.P).astype(np.float32)
    inv_w = np.array([[0.25]], np.float32)
    (got,) = compiled(x, a, b, inv_w)
    (want,) = model.hash_batch_graph(x, a, b, inv_w)
    assert (np.asarray(got) != np.asarray(want)).mean() < 1e-3


def test_manifest_shapes_consistent():
    assert aot.P >= 8 * 32  # supports the paper's largest L*M
    assert all(b % 64 == 0 for b in aot.HASH_BATCHES)
    assert all(n >= aot.K for _, n in aot.RANK_SHAPES)
