"""L2: the jax compute graphs the rust coordinator executes via PJRT.

Two entry points, both built on the L1 Pallas kernels:

  * ``hash_batch_graph``  - quantized p-stable projections for a batch of
    vectors against the full projection bank (all L tables' M functions
    concatenated into one ``P = L*M``-column matmul).
  * ``rank_graph``        - candidate ranking: masked squared distances +
    ``top_k`` selection, returning (distances, indices) of the k nearest
    *valid* candidates (rust pads candidate tiles to the artifact shape and
    passes the true count in ``n_valid``).

These are lowered once by ``aot.py`` per (shape-variant) and never traced at
serving time.
"""

import jax
import jax.numpy as jnp

from .kernels import hash_batch, proj_batch, sqdist


def hash_batch_graph(x, a, b, inv_w):
    """[B, D] x [D, P] -> [B, P] int32 quantized hash coordinates."""
    return (hash_batch(x, a, b, inv_w),)


def proj_batch_graph(x, a, b, inv_w):
    """[B, D] x [D, P] -> [B, P] float32 raw projections (multi-probe path)."""
    return (proj_batch(x, a, b, inv_w),)


def rank_graph(q, c, n_valid, k: int):
    """Rank candidates for a query batch.

    Args:
      q: ``[Bq, D]`` queries.
      c: ``[N, D]`` candidate vectors (rows >= n_valid are padding).
      n_valid: ``[1, 1]`` int32 count of real candidate rows.
      k: static top-k size baked into the artifact.

    Returns:
      ``(dists [Bq, k] f32, idx [Bq, k] i32)`` ascending by distance; padded
      slots (when n_valid < k) carry +inf / arbitrary indices.
    """
    d = sqdist(q, c)
    n = c.shape[0]
    nv = n_valid.reshape(()).astype(jnp.int32)
    mask = jnp.arange(n, dtype=jnp.int32)[None, :] >= nv
    d = jnp.where(mask, jnp.float32(jnp.inf), d)
    # NOTE: lax.top_k lowers to the `topk` HLO instruction, which the xla
    # crate's xla_extension 0.5.1 text parser rejects; a full lax.sort lowers
    # to plain `sort` HLO that round-trips. N <= 4096, so the O(N log N)
    # sort is noise next to the distance matmul.
    idx = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    sorted_d, sorted_i = jax.lax.sort((d, idx), dimension=1, num_keys=1)
    return sorted_d[:, :k], sorted_i[:, :k]
