"""Pure-jnp oracles for the Pallas kernels (the correctness reference)."""

import jax.numpy as jnp


def hash_batch_ref(x, a, b, inv_w):
    """Reference p-stable quantized projections: floor((x @ a + b) * inv_w)."""
    x = jnp.asarray(x, jnp.float32)
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    inv_w = jnp.float32(inv_w) if jnp.ndim(inv_w) == 0 else jnp.asarray(
        inv_w, jnp.float32
    ).reshape(())
    return jnp.floor((x @ a + b[None, :]) * inv_w).astype(jnp.int32)


def sqdist_ref(q, c):
    """Reference squared L2 distance matrix, direct (q - c)^2 form."""
    q = jnp.asarray(q, jnp.float32)
    c = jnp.asarray(c, jnp.float32)
    diff = q[:, None, :] - c[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def rank_ref(q, c, n_valid, k):
    """Reference top-k: indices+distances of the k nearest valid candidates."""
    d = sqdist_ref(q, c)
    n = c.shape[0]
    mask = jnp.arange(n)[None, :] >= n_valid
    d = jnp.where(mask, jnp.float32(jnp.inf), d)
    idx = jnp.argsort(d, axis=1)[:, :k]
    vals = jnp.take_along_axis(d, idx, axis=1)
    return vals, idx.astype(jnp.int32)
