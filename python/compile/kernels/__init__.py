# L1: Pallas kernels for the LSH hot spots.
#
# Two kernels cover the paper's compute-intensive inner loops:
#   * lsh_hash   - p-stable projection + quantization: floor((X @ A + b) / w)
#   * l2_distance - blocked squared-Euclidean distances via the
#                   ||q||^2 + ||c||^2 - 2 q.c matmul form (MXU-friendly)
#
# Both are lowered with interpret=True (CPU PJRT cannot execute Mosaic
# custom-calls); block shapes are still chosen as if targeting TPU VMEM/MXU
# and the estimate is documented in DESIGN.md / EXPERIMENTS.md SS Perf.
from .lsh_hash import hash_batch, proj_batch
from .l2_distance import sqdist

__all__ = ["hash_batch", "proj_batch", "sqdist"]
