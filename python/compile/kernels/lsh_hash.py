"""L1 Pallas kernel: p-stable LSH projection + quantization.

Computes ``H[i, j] = floor((X[i, :] . A[:, j] + b[j]) * inv_w)`` for a batch
of vectors ``X [B, D]`` against a bank of ``P`` projection directions
``A [D, P]`` (already transposed so the contraction is a plain matmul).

TPU mapping (see DESIGN.md SS Hardware-Adaptation): the paper's per-core
scalar dot-product loop becomes one MXU matmul per (row-tile x full bank);
the ``floor((. + b) * inv_w)`` quantization is a VPU epilogue fused into the
same kernel, so the projected values never round-trip to HBM.

VMEM budget at the default tile (TB=128, D=128, P=256, f32):
    X tile 64 KiB + A 128 KiB + b 1 KiB + out 128 KiB  ~= 321 KiB  << 16 MiB.
The grid walks row tiles only; A and b are re-used across all grid steps
(constant index_map), which a TPU backend keeps resident in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height: one MXU pass worth of rows.
DEFAULT_TB = 128


def _hash_kernel(x_ref, a_ref, b_ref, inv_w_ref, o_ref):
    x = x_ref[...]
    a = a_ref[...]
    # MXU matmul with f32 accumulation.
    acc = jnp.dot(x, a, preferred_element_type=jnp.float32)
    inv_w = inv_w_ref[0, 0]
    o_ref[...] = jnp.floor((acc + b_ref[...]) * inv_w).astype(jnp.int32)


def _proj_kernel(x_ref, a_ref, b_ref, inv_w_ref, o_ref):
    # Same projection, no quantization: the Query Receiver needs the raw
    # (a.v + b)/w values because their fractional parts drive the
    # multi-probe perturbation sequence (Lv et al. SS4).
    x = x_ref[...]
    a = a_ref[...]
    acc = jnp.dot(x, a, preferred_element_type=jnp.float32)
    inv_w = inv_w_ref[0, 0]
    o_ref[...] = (acc + b_ref[...]) * inv_w


def _call_bank_kernel(kernel, out_dtype, x, a, b, inv_w, tb):
    x = jnp.asarray(x, jnp.float32)
    a = jnp.asarray(a, jnp.float32)
    b2 = jnp.asarray(b, jnp.float32).reshape(1, -1)
    inv_w2 = jnp.asarray(inv_w, jnp.float32).reshape(1, 1)
    bsz, d = x.shape
    p = a.shape[1]

    tb = min(tb, bsz)
    pad = (-bsz) % tb
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    padded = bsz + pad

    out = pl.pallas_call(
        kernel,
        grid=(padded // tb,),
        in_specs=[
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((d, p), lambda i: (0, 0)),
            pl.BlockSpec((1, p), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, p), out_dtype),
        interpret=True,
    )(x, a, b2, inv_w2)
    return out[:bsz]


def hash_batch(x, a, b, inv_w, *, tb=DEFAULT_TB):
    """Quantized p-stable projections for a batch of vectors.

    Args:
      x: ``[B, D]`` float32 batch of data/query vectors.
      a: ``[D, P]`` float32 projection bank (each column one sampled ``a``).
      b: ``[P]`` float32 per-projection offsets, pre-sampled from U(0, w).
      inv_w: scalar (or ``[1, 1]``) float32, reciprocal of the bucket width.

    Returns:
      ``[B, P]`` int32 quantized hash coordinates ``h_j(x_i)``.
    """
    return _call_bank_kernel(_hash_kernel, jnp.int32, x, a, b, inv_w, tb)


def proj_batch(x, a, b, inv_w, *, tb=DEFAULT_TB):
    """Raw (un-floored) projections ``(x @ a + b) * inv_w`` — same shapes as
    :func:`hash_batch` but float32 output; `floor` gives the coordinates and
    the fractional parts drive multi-probe."""
    return _call_bank_kernel(_proj_kernel, jnp.float32, x, a, b, inv_w, tb)
