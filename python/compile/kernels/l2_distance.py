"""L1 Pallas kernel: blocked squared-Euclidean distance matrix.

Computes ``D[i, j] = ||Q[i] - C[j]||^2`` using the MXU-friendly expansion
``||q||^2 + ||c||^2 - 2 q.c`` so the dominant cost is a single matmul
``Q @ C^T`` per candidate tile instead of the paper's per-core scalar loop.

TPU mapping: candidates stream through VMEM in ``TN``-row tiles (BlockSpec
drives the HBM->VMEM schedule the paper implemented with per-node blocking);
the query block stays resident across the whole grid. Row norms are
recomputed per tile on the VPU - they are O(TN*D) against the O(Bq*TN*D)
matmul, a <1/Bq relative overhead, and recomputing avoids a second input
stream.

VMEM at the default tile (Bq<=16, TN=512, D=128, f32):
    Q 8 KiB + C tile 256 KiB + out 32 KiB ~= 296 KiB << 16 MiB.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Candidate-tile height.
DEFAULT_TN = 512


def _sqdist_kernel(q_ref, c_ref, o_ref):
    q = q_ref[...]
    c = c_ref[...]
    qn = jnp.sum(q * q, axis=1, keepdims=True)          # [Bq, 1]
    cn = jnp.sum(c * c, axis=1, keepdims=True)          # [TN, 1]
    dot = jnp.dot(q, c.T, preferred_element_type=jnp.float32)  # MXU
    o_ref[...] = qn + cn.T - 2.0 * dot


def sqdist(q, c, *, tn=DEFAULT_TN):
    """Squared L2 distances between every query and every candidate.

    Args:
      q: ``[Bq, D]`` float32 queries.
      c: ``[N, D]`` float32 candidates.

    Returns:
      ``[Bq, N]`` float32 squared distances.
    """
    q = jnp.asarray(q, jnp.float32)
    c = jnp.asarray(c, jnp.float32)
    bq, d = q.shape
    n = c.shape[0]

    tn = min(tn, n)
    pad = (-n) % tn
    if pad:
        c = jnp.pad(c, ((0, pad), (0, 0)))
    padded = n + pad

    out = pl.pallas_call(
        _sqdist_kernel,
        grid=(padded // tn,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (0, 0)),
            pl.BlockSpec((tn, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bq, tn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((bq, padded), jnp.float32),
        interpret=True,
    )(q, c)
    return out[:, :n]
