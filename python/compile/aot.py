"""AOT lowering: jax graphs -> HLO *text* artifacts for the rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run as ``python -m compile.aot --out ../artifacts`` (from ``python/``).
Emits one ``<name>.hlo.txt`` per shape variant plus ``manifest.txt`` that the
rust artifact registry parses:

    hash  <file>  b=<B> d=<D> p=<P>
    rank  <file>  bq=<Bq> n=<N> d=<D> k=<K>
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

D = 128          # SIFT dimensionality (fixed across the paper)
P = 256          # projection bank capacity: supports L*M <= 256 (e.g. 8x32)
K = 16           # top-k capacity (paper uses k=10; 16 is the padded slot)

HASH_BATCHES = [64, 256, 1024, 4096]
PROJ_BATCHES = [64, 256]
RANK_SHAPES = [(1, 256), (1, 1024), (1, 4096), (8, 1024), (16, 4096)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_hash(batch: int):
    spec = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.float32)
    return jax.jit(model.hash_batch_graph).lower(
        spec(batch, D), spec(D, P), spec(P), spec(1, 1)
    )


def lower_proj(batch: int):
    spec = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.float32)
    return jax.jit(model.proj_batch_graph).lower(
        spec(batch, D), spec(D, P), spec(P), spec(1, 1)
    )


def lower_rank(bq: int, n: int):
    f32 = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.float32)
    i32 = jax.ShapeDtypeStruct((1, 1), jnp.int32)
    fn = functools.partial(model.rank_graph, k=K)
    return jax.jit(fn).lower(f32(bq, D), f32(n, D), i32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = []
    for b in HASH_BATCHES:
        name = f"hash_b{b}_p{P}.hlo.txt"
        text = to_hlo_text(lower_hash(b))
        with open(os.path.join(args.out, name), "w") as f:
            f.write(text)
        manifest.append(f"hash {name} b={b} d={D} p={P}")
        print(f"wrote {name} ({len(text)} chars)")

    for b in PROJ_BATCHES:
        name = f"proj_b{b}_p{P}.hlo.txt"
        text = to_hlo_text(lower_proj(b))
        with open(os.path.join(args.out, name), "w") as f:
            f.write(text)
        manifest.append(f"proj {name} b={b} d={D} p={P}")
        print(f"wrote {name} ({len(text)} chars)")

    for bq, n in RANK_SHAPES:
        name = f"rank_q{bq}_n{n}_k{K}.hlo.txt"
        text = to_hlo_text(lower_rank(bq, n))
        with open(os.path.join(args.out, name), "w") as f:
            f.write(text)
        manifest.append(f"rank {name} bq={bq} n={n} d={D} k={K}")
        print(f"wrote {name} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
